"""Paper reproduction demo (§VI): both test applications, both bottleneck
settings, TCP vs App-aware — the core result of the paper in one script.

    PYTHONPATH=src python examples/stream_allocator_demo.py
"""
from repro.net import LinkKind, big_switch, fat_tree
from repro.streams import (
    compile_sim,
    parallelize,
    round_robin,
    simulate,
    trending_topics,
    trucking_iot,
)

CAPS = {"10Mbps": 1.25, "15Mbps": 1.875, "20Mbps": 2.5}


def main() -> None:
    for setting, topo_fn in (
        ("single-hop (up/downlink bottleneck)", lambda c: big_switch(8, c)),
        ("multi-hop (fat-tree, throttled internals)",
         lambda c: fat_tree(up=12.5).set_capacity(LinkKind.INTERNAL, c)),
    ):
        print(f"=== {setting} ===")
        for app_name, mk in (("TT", trending_topics), ("TI", trucking_iot)):
            for cap_name, cap in CAPS.items():
                topo = topo_fn(cap)
                g = parallelize(mk(), seed=0)
                sim = compile_sim(g, topo, round_robin(g, topo.n_machines))
                tcp = simulate(sim, "tcp", seconds=600.0)
                aa = simulate(sim, "appaware", seconds=600.0)
                dthpt = (aa.throughput_tps / tcp.throughput_tps - 1) * 100
                dlat = (1 - aa.avg_latency_s / tcp.avg_latency_s) * 100
                print(f"  {app_name} @{cap_name:7s}: "
                      f"throughput {tcp.throughput_tps:7.1f} -> "
                      f"{aa.throughput_tps:7.1f} t/s ({dthpt:+5.1f}%)   "
                      f"latency {tcp.avg_latency_s:6.1f} -> "
                      f"{aa.avg_latency_s:6.1f}s ({dlat:+5.1f}%)")


if __name__ == "__main__":
    main()
