"""§VII demo: five applications with 1..5 flows sharing one bottleneck.
TCP's flow-level fairness hands the many-flow app the biggest share;
App-Fair's EWMA grouping + strict priority + displacement equalizes the
apps (paper: Jain 0.84 -> 0.98+).

    PYTHONPATH=src python examples/multiapp_fairness.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import AppFairScheduler, jain_index, maxmin_rates


def main() -> None:
    n_apps = 5
    app_of_flow = np.concatenate([[a] * (a + 1) for a in range(n_apps)])
    F = len(app_of_flow)
    R = jnp.ones((F, 1), jnp.float32)
    cap = jnp.array([100.0])

    x = np.asarray(maxmin_rates(R, cap))
    tcp = np.array([x[app_of_flow == a].sum() for a in range(n_apps)])
    print("TCP     per-app Mb/s:", np.round(tcp, 1),
          " Jain:", round(float(jain_index(jnp.asarray(tcp))), 3))

    for alpha in (0.25, 0.5, 0.75, 1.0):
        sched = AppFairScheduler(n_apps, alpha=alpha, n_groups=5)
        state = sched.init()
        total = np.zeros(n_apps)
        prev = np.zeros(n_apps, np.float32)
        T = 60
        for _ in range(T):
            state, xf = sched.step(state, jnp.asarray(prev), R, cap,
                                   jnp.asarray(app_of_flow))
            xn = np.asarray(xf)
            per = np.array([xn[app_of_flow == a].sum()
                            for a in range(n_apps)])
            total += per
            prev = per.astype(np.float32)
        avg = total / T
        print(f"App-Fair(α={alpha:4.2f}) per-app:", np.round(avg, 1),
              " Jain:", round(float(jain_index(jnp.asarray(avg))), 3))


if __name__ == "__main__":
    main()
