"""In-run network dynamics demo: a mid-run link failure and recovery,
TCP vs the paper's app-aware allocator.

Links 0-3 drop to 10% capacity at t=50s and recover at t=70s — *inside*
one simulation run (a `LinkSchedule`, evaluated per tick in the scan).
The interesting regime is the transient: how deep does throughput dip,
how fast does each policy recover, and who ends up better off after the
event (the paper's Fig. 5/12 question, which a static capacity grid can
never ask).

    PYTHONPATH=src python examples/dynamic_failure.py
"""
from __future__ import annotations

from repro.net import big_switch, link_failure_schedule
from repro.streams import (
    compile_sim,
    parallelize,
    round_robin,
    simulate,
    trending_topics,
)

T_FAIL, T_RECOVER = 50.0, 70.0
SECONDS = 120.0


def main() -> None:
    g = parallelize(trending_topics(), seed=0)
    topo = big_switch(8, 1.25)
    sched = link_failure_schedule(topo, [0, 1, 2, 3], t_fail=T_FAIL,
                                  t_recover=T_RECOVER, degrade=0.1)
    sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)

    print(f"{'policy':10s} {'tput t/s':>9s} {'post-event':>11s} "
          f"{'dip':>6s} {'recovery s':>11s}")
    for policy in ("tcp", "appaware"):
        r = simulate(sim, policy, seconds=SECONDS, dt=0.5)
        i = int(T_FAIL / r.dt)
        post = float(r.sink_mb[i:].mean() / r.dt * r.tuples_per_mb)
        print(f"{policy:10s} {r.throughput_tps:9.1f} {post:11.1f} "
              f"{r.dip_depth(T_FAIL):6.2f} {r.recovery_time_s(T_FAIL):11.1f}")


if __name__ == "__main__":
    main()
