"""Batched serving example: prefill + slot-based continuous batching over a
registered architecture (greedy decode).

    PYTHONPATH=src python examples/serve_decode.py --arch yi-6b --requests 6
"""
import argparse
import time

import jax
import numpy as np

from repro.models.registry import get_config, get_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, max_len=args.prompt_len + args.new_tokens + 8,
                      batch_slots=args.slots)
    eng.load(params)

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                    dtype=np.int32),
                max_new_tokens=args.new_tokens)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"arch={args.arch} (reduced) — {len(reqs)} requests, "
          f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s on CPU)")
    for i, r in enumerate(reqs):
        print(f"  req{i}: {r.out}")


if __name__ == "__main__":
    main()
