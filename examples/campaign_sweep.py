"""Streaming campaign demo: a 2048-scenario capacity-grid × failure-axis
study with bounded memory — no trajectory array is ever held.

Builds `campaign_fleet(2048)` — {TT, TI} × the paper's {10, 15, 20 Mbps}
grid × {static, in-run link failure, in-run diurnal cycle}, each scenario
jittered by a seeded rng — and streams it through
`FleetRunner.run_campaign`'s three-stage pipeline: the bucket plan is
computed over the whole campaign, scenarios flow through fixed-shape
chunks that all reuse a handful of compiled executables, chunk k+1 is
packed into rotating host slots and its H2D copy prefetched by the
transfer worker while chunk k runs on-device (`chunk_rows="auto"` would
size the chunks from the measured backend calibration; with >1 local
device the chunk stream shards round-robin across devices), and only the
on-device metric epilogue's [rows, 7] summary ever crosses the device
boundary. Host staging stays ≤ 3 chunk-slots per stream and device
residency ≤ 2 in-flight chunks however large the campaign — `last_stats`
prints the evidence.

The per-axis table below is pure `CampaignResult` column math: group the
[N, 7] metric matrix by the generator's (app, capacity, kind) axes and
aggregate — a fleet-scale study summarized without ever materializing a
[N, T, ...] array.

    PYTHONPATH=src python examples/campaign_sweep.py
"""
from __future__ import annotations

import time

import numpy as np

from repro.streams import FleetRunner, campaign_fleet, compile_fleet

N = 2048
SECONDS = 120.0
POLICY = "tcp"


def main() -> None:
    scenarios = campaign_fleet(N, seed=0)
    sims = compile_fleet(scenarios)
    runner = FleetRunner()
    print(f"campaign: {N} scenarios, policy={POLICY}, "
          f"{SECONDS:.0f}s horizon (streaming, metrics-only)\n")

    t0 = time.time()
    cr = runner.run_campaign(sims, POLICY, seconds=SECONDS)
    wall = time.time() - t0
    st = runner.last_stats

    # ---- per-axis summary straight off the [N, 7] metric matrix ----
    # scenario names encode the axes: "<app>_<kind><k>"; capacity cycles
    # with the generator's index, so recover it the same way
    caps_cycle = ("10Mbps", "15Mbps", "20Mbps")
    axis = [(s.name.split("_")[0],                       # app
             caps_cycle[(k // 2) % 3],                   # capacity
             s.name.split("_")[1].rstrip("0123456789"))  # kind
            for k, s in enumerate(scenarios)]

    def table(title, key_of):
        groups: dict[str, np.ndarray] = {}
        for i, key in enumerate(map(key_of, axis)):
            groups.setdefault(key, []).append(i)
        print(f"{title:16s} {'n':>5s} {'tput t/s':>9s} {'lat s':>7s} "
              f"{'util':>6s} {'dip':>6s} {'rec s':>7s}")
        for key in sorted(groups):
            idx = np.asarray(groups[key])
            rec = cr.recovery_time_s[idx]
            rec_med = float(np.median(rec[np.isfinite(rec)])) \
                if np.isfinite(rec).any() else float("inf")
            print(f"{key:16s} {len(idx):5d} "
                  f"{cr.throughput_tps[idx].mean():9.1f} "
                  f"{cr.avg_latency_s[idx].mean():7.2f} "
                  f"{cr.utilization[idx].mean():6.3f} "
                  f"{cr.dip_depth[idx].mean():6.3f} {rec_med:7.1f}")
        print()

    table("by app", lambda a: a[0])
    table("by capacity", lambda a: a[1])
    table("by schedule", lambda a: a[2])
    table("app x kind", lambda a: f"{a[0]}/{a[2]}")

    # ---- the memory story ----
    print(f"wall: {wall:.1f}s total ({N / wall:.0f} scenarios/s), "
          f"{st['n_chunks']} chunks over {st['n_buckets']} buckets, "
          f"{runner.compile_cache_size()} compiled executables")
    print(f"host staging: peak {st['peak_staged_rows']} rows "
          f"({st['peak_staged_bytes'] / 1e6:.1f} MB) — rotating-slot "
          f"bound 3 x {st['chunk_rows']} rows x {st['n_streams']} "
          f"stream(s), independent of N")
    print(f"staging overlap: {st['overlap_fraction']:.0%} of "
          f"{st['stage_s']:.2f}s staging hidden behind device compute; "
          f"metric fetches blocked {st['block_s']:.2f}s")
    print(f"H2D prefetch: {st['transfer_s']:.2f}s of copies on the "
          f"transfer worker, {st['transfer_overlap']:.0%} overlapped "
          f"(dispatch thread waited {st['transfer_wait_s']:.2f}s)")
    held = cr.metrics.nbytes + cr.tuples_per_mb.nbytes
    print(f"retained per campaign: {held / 1e3:.0f} kB of metrics "
          f"({N} x {cr.metrics.shape[1]} floats) — no [T, ...] "
          f"trajectory was transferred or kept "
          f"(results={cr.results!r})")


if __name__ == "__main__":
    main()
