"""Fleet simulation demo: a capacity × workload parameter sweep in ONE
fused kernel dispatch per policy.

Builds the paper's §VI grid — {TT, TI} × {10, 15, 20 Mbps} × {single-hop,
multi-hop} — as 12 scenarios and runs TCP and App-aware across the whole
grid through a persistent `FleetRunner`: every shape bucket's
vmap-over-scan lives inside one jitted executable, so a warm sweep is a
single kernel launch per policy. The second (warm) sweep shows what a
repeat study costs once the executables are cached — the runner's
`last_stats` reports the dispatch count and bucket structure behind each
number. Compare `stream_allocator_demo.py`, which walks the same grid
with 12 separate compile+run cycles per policy.

    PYTHONPATH=src python examples/fleet_sweep.py
"""
from __future__ import annotations

import time

from repro.streams import FleetRunner, capacity_sweep, compile_fleet

SECONDS = 600.0


def main() -> None:
    scenarios = capacity_sweep(multihop=False) + capacity_sweep(multihop=True)
    sims = compile_fleet(scenarios)
    runner = FleetRunner()
    print(f"fleet: {len(sims)} scenarios "
          f"(one fused executable per policy)\n")

    t0 = time.time()
    tcp = runner.run(sims, "tcp", seconds=SECONDS)
    tcp_stats = dict(runner.last_stats)
    aa = runner.run(sims, "appaware", seconds=SECONDS)
    aa_stats = dict(runner.last_stats)
    cold = time.time() - t0

    # warm repeat: executables cached, staging reused — a parameter
    # re-study pays pure execution
    t0 = time.time()
    runner.run(sims, "tcp", seconds=SECONDS)
    runner.run(sims, "appaware", seconds=SECONDS)
    warm = time.time() - t0

    print(f"{'scenario':28s} {'tcp t/s':>9s} {'appaware t/s':>13s} {'Δ%':>7s}")
    for sc, r_tcp, r_aa in zip(scenarios, tcp, aa):
        gain = (r_aa.throughput_tps / max(r_tcp.throughput_tps, 1e-9) - 1) * 100
        print(f"{sc.name:28s} {r_tcp.throughput_tps:9.1f} "
              f"{r_aa.throughput_tps:13.1f} {gain:+6.1f}%")
    print(f"\nwhole sweep (both policies, {SECONDS:.0f}s runs): "
          f"{cold:.1f}s cold (compiles included), {warm:.2f}s warm repeat")
    for name, st in (("tcp", tcp_stats), ("appaware", aa_stats)):
        print(f"  {name}: {st['n_dispatches']} kernel dispatch(es), "
              f"{st['n_buckets']} shape bucket(s) in one executable, "
              f"padded rows {st['rows']}")
    per_scen = warm / 2 / len(sims) * 1e3
    print(f"  warm cost: {per_scen:.1f} ms/scenario/policy")


if __name__ == "__main__":
    main()
