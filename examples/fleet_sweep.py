"""Fleet simulation demo: a capacity × workload parameter sweep in ONE
batched call per policy.

Builds the paper's §VI grid — {TT, TI} × {10, 15, 20 Mbps} × {single-hop,
multi-hop} — as 12 scenarios, stacks them to a common padded shape, and
runs TCP and App-aware across the whole grid with two `simulate_many`
calls (one vmapped XLA program each). Compare `stream_allocator_demo.py`,
which walks the same grid with 12 separate compile+run cycles per policy.

    PYTHONPATH=src python examples/fleet_sweep.py
"""
from __future__ import annotations

import time

from repro.streams import capacity_sweep, compile_fleet, simulate_many

SECONDS = 600.0


def main() -> None:
    scenarios = capacity_sweep(multihop=False) + capacity_sweep(multihop=True)
    sims = compile_fleet(scenarios)
    print(f"fleet: {len(sims)} scenarios "
          f"(padded to a common shape, one compile per policy)\n")

    t0 = time.time()
    tcp = simulate_many(sims, "tcp", seconds=SECONDS)
    aa = simulate_many(sims, "appaware", seconds=SECONDS)
    wall = time.time() - t0

    print(f"{'scenario':28s} {'tcp t/s':>9s} {'appaware t/s':>13s} {'Δ%':>7s}")
    for sc, r_tcp, r_aa in zip(scenarios, tcp, aa):
        gain = (r_aa.throughput_tps / max(r_tcp.throughput_tps, 1e-9) - 1) * 100
        print(f"{sc.name:28s} {r_tcp.throughput_tps:9.1f} "
              f"{r_aa.throughput_tps:13.1f} {gain:+6.1f}%")
    print(f"\nwhole sweep (both policies, {SECONDS:.0f}s runs): {wall:.1f}s wall")


if __name__ == "__main__":
    main()
