"""Quickstart: the two halves of the framework in one minute.

  1. the paper's allocator beating TCP on the TrendingTopics stream app;
  2. a tiny LM training for 50 steps and decoding a few tokens.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticLM
from repro.models.registry import get_config, get_model
from repro.net import big_switch
from repro.serve.engine import Request, ServeEngine
from repro.streams import compile_sim, parallelize, round_robin, simulate, trending_topics
from repro.train.optim import AdamW
from repro.train.step import make_train_step


def stream_demo():
    print("=== 1. SDN-style bandwidth allocation (paper Alg. 1) ===")
    g = parallelize(trending_topics(), seed=0)
    sim = compile_sim(g, big_switch(8, 1.25), round_robin(g, 8))
    tcp = simulate(sim, "tcp", seconds=300.0)
    aa = simulate(sim, "appaware", seconds=300.0)
    print(f"  TCP      : {tcp.throughput_tps:7.1f} tuples/s, "
          f"latency {tcp.avg_latency_s:6.1f}s")
    print(f"  App-aware: {aa.throughput_tps:7.1f} tuples/s, "
          f"latency {aa.avg_latency_s:6.1f}s "
          f"(+{(aa.throughput_tps / tcp.throughput_tps - 1) * 100:.0f}% throughput)")


def lm_demo():
    print("=== 2. LM training + serving (same substrate as the dry-run) ===")
    cfg = get_config("qwen1.5-0.5b").reduced(vocab=128, n_layers=2)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(api, opt))
    pipe = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8)
    for i, b in enumerate(pipe.batches(50)):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 10 == 0:
            print(f"  step {i:3d}  loss {float(m['loss']):.3f}")
    eng = ServeEngine(api, max_len=96)
    eng.load(params)
    req = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=8)
    eng.run([req])
    print(f"  decoded: {req.out}")


if __name__ == "__main__":
    stream_demo()
    lm_demo()
