import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Cross-layer collective scheduling demo (DESIGN.md §2): compile a train
step on a small mesh, extract its collective flows from the HLO, and run the
paper's allocator over them to produce the issue order / chunking plan.

    PYTHONPATH=src python examples/comm_schedule.py --arch qwen1.5-0.5b
"""
import argparse

import jax
import numpy as np

from repro.core.scheduler import extract_flows, plan_schedule
from repro.launch.mesh import _mk
from repro.launch.shardings import batch_shardings, opt_shardings, param_shardings
from repro.models.registry import ShapeSpec, get_config, get_model
from repro.sharding.policy import sharding_policy
from repro.train.optim import AdamW
from repro.train.step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(d_model=256, n_layers=2, vocab=1024)
    api = get_model(cfg)
    mesh = _mk((4, 2), ("data", "model"))
    spec = ShapeSpec("demo", 256, 8, "train")
    with sharding_policy(mesh):
        opt = AdamW(lr=1e-3)
        step = make_train_step(api, opt)
        params_ab = api.abstract_params()
        opt_ab = jax.eval_shape(opt.init, params_ab)
        p_sh = param_shardings(mesh, api)
        ispecs = api.input_specs(spec)
        shardings = (p_sh, opt_shardings(mesh, p_sh, opt_ab),
                     batch_shardings(mesh, ispecs))
        compiled = jax.jit(step, in_shardings=shardings).lower(
            params_ab, opt_ab, ispecs).compile()

    hlo = compiled.as_text()
    mesh_axes = {a: mesh.shape[a] for a in mesh.axis_names}
    flows = extract_flows(hlo, mesh_axes)
    print(f"extracted {len(flows)} collective flows from the compiled step")
    by_axis = {}
    for f in flows:
        by_axis.setdefault(f.axis, []).append(f)
    for axis, fs in by_axis.items():
        mb = sum(f.bytes for f in fs) / 1e6
        print(f"  axis {axis:6s}: {len(fs):3d} flows, {mb:8.1f} MB/step")

    compute_s = float(compiled.cost_analysis().get("flops", 1e9)) / 197e12
    sched = plan_schedule(flows, mesh_axes, step_compute_s=max(compute_s, 1e-3))
    print(f"allocator schedule: total comm {sched.est_total_comm_s * 1e3:.2f} ms, "
          f"exposed (not overlapped) {sched.est_exposed_s * 1e3:.2f} ms")
    print("top-5 most urgent flows (paper's min-max transfer-time order):")
    for i in sched.order[:5]:
        f = flows[i]
        print(f"  {f.kind:18s} axis={f.axis:6s} {f.bytes / 1e6:8.2f} MB "
              f"rate={sched.rates[i] / 1e9:6.2f} GB/s chunks={sched.chunks[i]}")


if __name__ == "__main__":
    main()
