"""End-to-end training driver (deliverable b): train an LM for a few hundred
steps with the full production substrate — fault-tolerant driver, async
checkpointing, restart, optional gradient compression — on any registered
architecture at a CPU-scaled size.

    PYTHONPATH=src python examples/train_lm.py --arch qwen1.5-0.5b \
        --steps 300 --preset small
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 50

Presets: small (~3M params, fast on CPU), 100m (~100M params — the
'train a ~100M model' configuration; a few hundred steps ≈ hours on CPU,
minutes on one TPU host).
"""
import argparse
import time

import numpy as np

from repro.data.pipeline import SyntheticLM
from repro.models.registry import get_config, get_model
from repro.train.driver import DriverConfig, TrainDriver
from repro.train.optim import AdamW, warmup_cosine

PRESETS = {
    "small": dict(d_model=128, n_layers=4, n_heads=4, n_kv_heads=4,
                  d_ff=512, vocab=512, head_dim=None),
    "100m": dict(d_model=640, n_layers=12, n_heads=10, n_kv_heads=10,
                 d_ff=2560, vocab=32768, head_dim=None),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="step at which to inject a failure (tests restart)")
    args = ap.parse_args()

    import jax

    cfg = get_config(args.arch).reduced(**PRESETS[args.preset])
    api = get_model(cfg)
    n = api.count_params()
    print(f"arch={args.arch} preset={args.preset} params={n / 1e6:.1f}M")

    opt = AdamW(lr=warmup_cosine(3e-3, warmup=20, total=args.steps))
    pipe = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch)

    extra = None
    if cfg.family == "vlm":
        vis = np.zeros((args.batch, cfg.n_vis_tokens, cfg.d_model),
                       np.float32)
        extra = lambda step: {"vis_embeds": jax.numpy.asarray(vis)}
    if cfg.family == "encdec":
        fr = np.zeros((args.batch, 64, cfg.d_model), np.float32)
        extra = lambda step: {"frames": jax.numpy.asarray(fr)}

    dcfg = DriverConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir)
    drv = TrainDriver(
        api, opt, pipe, dcfg,
        failure_at={args.inject_failure} if args.inject_failure >= 0 else None,
        extra_batch=extra)
    t0 = time.time()
    _, _, step = drv.run()
    dt = time.time() - t0
    losses = [m["loss"] for m in drv.metrics]
    print(f"finished {step} steps in {dt:.1f}s "
          f"({dt / max(len(losses), 1):.2f}s/step)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(uniform baseline {np.log(cfg.vocab):.3f})")
    for s, e in drv.events:
        print(f"  event@{s}: {e}")


if __name__ == "__main__":
    main()
