"""repro: 'On SDN-Enabled Online and Dynamic Bandwidth Allocation for
Stream Analytics' (Aljoby et al., ICNP'18/JSAC'19) as a production-grade
multi-pod JAX/TPU framework. See DESIGN.md."""

__version__ = "0.1.0"
