"""§Dry-run summary table: per (arch × shape × mesh): compile status,
lower/compile seconds, per-device argument/peak memory, collective count.

    PYTHONPATH=src python -m repro.launch.dryrun_report
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def main() -> None:
    recs = [json.loads(f.read_text()) for f in sorted(RESULTS.glob("*.json"))]
    base = [r for r in recs if r.get("rules", "baseline") == "baseline"
            and "__cg" not in str(r) ]
    print("| arch | shape | mesh | status | compile s | args GB/dev | "
          "peak GB/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|")
    n_ok = n_fail = 0
    for r in base:
        if r.get("ok"):
            n_ok += 1
            m = r.get("memory", {})
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r.get('compile_s', 0):.1f} | "
                  f"{m.get('argument_size_in_bytes', 0) / 1e9:.2f} | "
                  f"{m.get('peak_memory_in_bytes', 0) / 1e9:.2f} | "
                  f"{r.get('collectives_raw', {}).get('count', '?')} |")
        else:
            n_fail += 1
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | | "
                  f"| {r.get('error', '')[:60]} |")
    print(f"\n**{n_ok} cells compiled, {n_fail} failed.**")


if __name__ == "__main__":
    main()
