"""Roofline report generator (deliverable g).

Reads results/dryrun/*.json and emits the §Roofline markdown table:
three terms (seconds), dominant bottleneck, MODEL_FLOPS/HLO ratio, and a
one-line improvement note per (arch × shape × mesh).

    PYTHONPATH=src python -m repro.launch.roofline [--mesh pod_16x16]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def memory_floor_s(rec: dict, tp: int = 16) -> float:
    """Analytic HBM-traffic floor per device (perfectly fused kernels, no
    score materialization): weight reads (gathered copies at compute dtype,
    f32 in the baseline), optimizer state r/w on sharded storage,
    activation/residual traffic, KV-cache r/w. The HLO 'bytes accessed' is
    an UNFUSED upper bound — the truth lies between; both are reported."""
    from repro.models.registry import get_config

    chips = rec["n_chips"]
    P = rec["params"]
    cfg = get_config(rec["arch"])
    dtype_w = 4.0            # baseline keeps f32 gathers (cast-once lever)
    toks_dev = rec["global_batch"] * max(rec["seq_len"], 1) / max(chips / tp, 1)
    if rec["kind"] == "decode":
        toks_dev = rec["global_batch"] / max(chips / tp, 1)
    d = cfg.d_model
    L = cfg.n_layers + cfg.n_enc_layers
    act = toks_dev * d * 2.0 * L * 12.0        # ~12 r/w per layer, bf16
    if rec["kind"] == "train":
        weights = 3.0 * P * dtype_w / tp       # fwd + bwd + remat reads
        opt = 12.0 * P * 4.0 / chips           # m,v r/w + grad r/w + update
        return (weights + opt + act) / HBM_BW
    weights = P * dtype_w / tp
    cache = 0.0
    if rec["kind"] == "decode":
        # read the whole cache slice once per token
        cache = rec["seq_len"] * rec["global_batch"] * d * 2.0 * 2.0 * L / chips
    return (weights + cache + act) / HBM_BW


def cell_terms(rec: dict) -> dict:
    chips = rec["n_chips"]
    flops_dev = rec["flops"]              # per-device HLO module numbers
    bytes_dev = rec["bytes_accessed"]
    coll_dev = rec["collectives"].get("total", 0.0)
    t_c = flops_dev / PEAK_FLOPS_BF16
    t_m = bytes_dev / HBM_BW              # unfused upper bound (HLO)
    t_mf = memory_floor_s(rec)            # fused analytic floor
    t_n = coll_dev / ICI_BW
    # bottleneck classification uses the memory FLOOR: the HLO byte count
    # assumes zero fusion and over-ranks memory for every cell
    dom = max((t_c, "compute"), (t_mf, "memory"), (t_n, "collective"))[1]
    if rec["kind"] == "train":
        tokens, mult = rec["global_batch"] * rec["seq_len"], 6
    elif rec["kind"] == "prefill":
        tokens, mult = rec["global_batch"] * rec["seq_len"], 2
    else:
        tokens, mult = rec["global_batch"], 2
    model_flops = mult * rec["active_params"] * tokens
    ratio = model_flops / max(flops_dev * chips, 1.0)
    bound = max(t_c, t_mf, t_n)
    return dict(t_c=t_c, t_m=t_m, t_mf=t_mf, t_n=t_n, dominant=dom,
                ratio=ratio, bound=bound, frac=t_c / max(bound, 1e-12),
                model_flops=model_flops)


NOTES = {
    ("compute",): "compute-bound: good — push MXU util (fused kernels, bf16)",
    ("memory",): "HBM-bound: increase arithmetic intensity "
                 "(fuse, larger tiles, avoid score materialization)",
    ("collective",): "collective-bound: cut FSDP/SP traffic "
                     "(bf16 gathers, reduce-scatter grads, less model-parallel "
                     "for small archs, overlap via allocator schedule)",
}


def improvement_note(rec: dict, t: dict) -> str:
    if t["dominant"] == "collective":
        c = rec["collectives"]
        top = max((k for k in ("all-gather", "all-reduce", "reduce-scatter",
                               "all-to-all", "collective-permute")),
                  key=lambda k: c.get(k, 0))
        return f"cut {top} ({c.get(top, 0) / 1e9:.0f} GB/dev): " + \
            NOTES[("collective",)]
    return NOTES[(t["dominant"],)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16",
                    help="pod_16x16 | multipod_2x16x16 | all")
    args = ap.parse_args()
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if args.mesh != "all" and rec.get("mesh") != args.mesh:
            continue
        if not rec.get("ok"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | FAILED: "
                        f"{rec.get('error', '?')[:60]} | | | | | | | |")
            continue
        t = cell_terms(rec)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {t['t_c']:.4f} | "
            f"{t['t_mf']:.4f} | {t['t_m']:.4f} | {t['t_n']:.4f} | "
            f"**{t['dominant']}** | {t['ratio']:.3f} | {t['frac']:.3f} | "
            f"{improvement_note(rec, t)} |")
    print(f"### Roofline — {args.mesh} "
          "(terms in seconds/step; per assignment constants)")
    print("| arch | shape | compute | mem(floor) | mem(HLO,unfused) | "
          "collective | bottleneck | MODEL/HLO | roofline-frac | "
          "what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
