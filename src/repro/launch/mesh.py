"""Mesh construction (assignment-mandated shapes).

``make_production_mesh`` is a FUNCTION — importing this module never touches
jax device state. Single pod: 16×16 = 256 chips ("data", "model");
multi-pod: 2×16×16 = 512 chips ("pod", "data", "model") — the "pod" axis is
the DCN dimension.
"""
from __future__ import annotations

import math

import jax


def _mk(shape, axes):
    # AxisType landed after jax 0.4.x; Auto is the default there anyway
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if axis_type is None else {
        "axis_types": (axis_type.Auto,) * len(axes)}
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU smoke runs, small slices)."""
    n = len(jax.devices())
    mp = math.gcd(model_parallel, n)
    return _mk((n // mp, mp), ("data", "model"))


# --- TPU v5e hardware constants (roofline, per assignment) -----------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link
