import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and only the dry-run) fakes 512 host devices so the
# production meshes (16x16 single-pod, 2x16x16 multi-pod) can be built.

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input-shape) cell and both production meshes:
lower + compile the appropriate step (train_step / prefill / serve decode),
print ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes for
§Roofline), and parse collective traffic from the compiled HLO. Results are
cached as JSON under ``results/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k \
      --mesh both -v
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (
    SERVE_RULES,
    TRAIN_RULES,
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_shardings,
    replicated,
)
from repro.models.registry import (
    ShapeSpec,
    get_config,
    get_model,
    list_archs,
    shapes_for,
)
from repro.sharding.policy import sharding_policy
from repro.train.optim import AdamW
from repro.train.step import make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _decode_pos(spec: ShapeSpec) -> int:
    return spec.seq_len - 1


def build_lowerable(api, spec: ShapeSpec, mesh, loss_unroll: bool = False,
                    rules_over: dict | None = None,
                    constrain_grads: bool = False):
    """Returns (fn, abstract_args, in_shardings) for the cell's step."""
    cfg = api.cfg
    ispecs = api.input_specs(spec)

    if spec.kind == "train":
        rules = dict(TRAIN_RULES, **(rules_over or {}))
        with sharding_policy(mesh, rules):
            opt = AdamW(lr=1e-4)
            step = make_train_step(api, opt, loss_unroll=loss_unroll,
                                   constrain_grads=constrain_grads)
            params_ab = api.abstract_params()
            opt_ab = jax.eval_shape(opt.init, params_ab)
            p_sh = param_shardings(mesh, api, rules)
            args = (params_ab, opt_ab, ispecs)
            shardings = (p_sh, opt_shardings(mesh, p_sh, opt_ab),
                         batch_shardings(mesh, ispecs, rules))
            return step, args, shardings, rules

    rules = dict(SERVE_RULES, **(rules_over or {}))
    with sharding_policy(mesh, rules):
        params_ab = api.abstract_params()
        p_sh = param_shardings(mesh, api, rules)
        if spec.kind == "prefill":
            # vlm: the cache must also hold the vision prefix
            vis = cfg.n_vis_tokens if cfg.family == "vlm" else 0

            def fn(params, batch):
                return api.prefill(params, batch, spec.seq_len + vis)
            args = (params_ab, ispecs)
            shardings = (p_sh, batch_shardings(mesh, ispecs, rules))
            return fn, args, shardings, rules

        # decode: one new token against a cache of seq_len
        cache_ab = jax.eval_shape(
            lambda: api.init_cache(spec.global_batch, spec.seq_len))
        c_sh = cache_shardings(mesh, cache_ab, rules)

        def fn(params, cache, tokens, pos):
            return api.decode(params, cache, tokens, pos)

        args = (params_ab, cache_ab, ispecs["tokens"],
                jax.ShapeDtypeStruct((), jnp.int32))
        shardings = (p_sh, c_sh,
                     batch_shardings(mesh, {"tokens": ispecs["tokens"]},
                                     rules)["tokens"],
                     replicated(mesh))
        return fn, args, shardings, rules


# ---------------------------------------------------------------------------
# Cost probes: HloCostAnalysis counts rolled `while` bodies ONCE, so the
# full (scan-over-layers) artifact under-reports FLOPs/bytes/collectives.
# We therefore compile small probe variants with ALL scans unrolled
# (scan_unroll/ssd_unroll/loss_unroll) at 2 depths × (1 or 3) sequence
# lengths and extrapolate: linear in depth (exact — all archs are
# depth-linear), quadratic in seq (exact for attention; SSD/MoE terms are
# linear, absorbed by the fit). Decode cells have no seq-dependent loops,
# so they are probed at the full cache length (depth-only, exact).
# ---------------------------------------------------------------------------
import dataclasses as _dc

import numpy as _np

PROBE_KEYS = ("flops", "bytes_accessed")


def _depth_variants(cfg):
    """(cfg_a, cfg_b, units_a, units_b, units_full) — depth in 'units'."""
    # keep remat as in the real cell: the recompute FLOPs are part of the
    # executed program (the MODEL_FLOPS/HLO ratio is meant to expose them)
    probe = dict(scan_unroll=True, ssd_unroll=True)
    if cfg.family == "hybrid":
        per = cfg.hybrid_attn_every
        tail = cfg.n_layers % per
        return (_dc.replace(cfg, n_layers=per + tail, **probe),
                _dc.replace(cfg, n_layers=2 * per + tail, **probe),
                1, 2, cfg.n_layers // per)
    if cfg.family == "encdec":
        return (_dc.replace(cfg, n_layers=1, n_enc_layers=1, **probe),
                _dc.replace(cfg, n_layers=2, n_enc_layers=2, **probe),
                1, 2, cfg.n_layers)
    return (_dc.replace(cfg, n_layers=1, **probe),
            _dc.replace(cfg, n_layers=2, **probe),
            1, 2, cfg.n_layers)


def _probe_one(cfg_p, spec, mesh, rules_over=None, constrain_grads=False):
    api = get_model(cfg_p)
    fn, args, shardings, rules = build_lowerable(api, spec, mesh,
                                                 loss_unroll=True,
                                                 rules_over=rules_over,
                                                 constrain_grads=constrain_grads)
    with sharding_policy(mesh, rules):
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = hlo_stats.collective_stats(compiled.as_text())
    rec = {"flops": float(cost.get("flops", 0.0)),
           "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    for k in hlo_stats.COLLECTIVES + ("total",):
        rec[f"coll_{k}"] = float(coll.get(k, 0))
    return rec


def _fit_eval(xs, ys, x_full, deg: int = 2):
    xs = _np.asarray(xs, float)
    ys = _np.asarray(ys, float)
    if len(xs) == 1:
        return float(ys[0])
    deg = min(len(xs) - 1, deg)
    coef = _np.polyfit(xs, ys, deg)
    return float(max(_np.polyval(coef, x_full), 0.0))


# fit degree per metric: FLOPs/bytes have genuine quadratic-in-seq terms
# (attention scores); collective traffic is linear in seq (weight gathers
# constant + activation gathers linear) — extrapolating a quadratic through
# three near-collinear points 8x beyond their range explodes/negates.
def _fit_deg(key: str) -> int:
    return 1 if key.startswith("coll_") else 2


def probe_costs(cfg, spec: ShapeSpec, mesh, rules_over=None,
                constrain_grads=False) -> dict:
    cfg_a, cfg_b, ua, ub, ufull = _depth_variants(cfg)
    if spec.kind == "decode":
        seqs = [spec.seq_len]           # no seq-dependent rolled loops
    else:
        seqs = sorted({min(spec.seq_len, s) for s in (1024, 2048, 4096)})
    keys = None
    per_depth = []
    raw = []
    for cfg_p in (cfg_a, cfg_b):
        recs = []
        for s in seqs:
            sp = ShapeSpec(spec.name, s, spec.global_batch, spec.kind)
            recs.append(_probe_one(cfg_p, sp, mesh, rules_over,
                                   constrain_grads))
        raw.append(recs)
        keys = keys or list(recs[0])
        per_depth.append({k: _fit_eval(seqs, [r[k] for r in recs],
                                       spec.seq_len, _fit_deg(k))
                          for k in keys})
    fa, fb = per_depth
    out = {}
    for k in keys:
        out[k] = fa[k] + (fb[k] - fa[k]) * (ufull - ua) / (ub - ua)
    out["probe_seqs"] = seqs
    out["probe_units"] = [ua, ub, ufull]
    out["probe_raw"] = raw  # per-depth, per-seq metric points (refittable)
    return out


def run_cell(arch: str, spec: ShapeSpec, multi_pod: bool,
             verbose: bool = False, rules_name: str = "baseline",
             constrain_grads: bool = False, cast_once: bool = False,
             skip_probes: bool = False) -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    suffix = "" if rules_name == "baseline" else f"__{rules_name}"
    if constrain_grads:
        suffix += "__cg"
    if cast_once:
        suffix += "__bf16g"
    out_path = RESULTS / f"{arch}__{spec.name}__{mesh_name}{suffix}.json"
    if out_path.exists():
        rec = json.loads(out_path.read_text())
        if rec.get("ok"):
            return rec

    cfg = get_config(arch)
    if cast_once:
        import dataclasses as __dc
        cfg = __dc.replace(cfg, cast_once=True)
    api = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    from repro.launch.shardings import PRESETS
    rules_over = PRESETS[rules_name]
    rec = {"arch": arch, "shape": spec.name, "mesh": mesh_name,
           "rules": rules_name,
           "kind": spec.kind, "seq_len": spec.seq_len,
           "global_batch": spec.global_batch,
           "n_chips": mesh.devices.size,
           "params": api.count_params(),
           "active_params": api.active_params(), "ok": False}
    try:
        fn, args, shardings, rules = build_lowerable(
            api, spec, mesh, rules_over=rules_over,
            constrain_grads=constrain_grads)
        # donate params/opt-state (train) and cache (decode): the updated
        # state reuses the input buffers — without this, params+opt+grads
        # coexist and the biggest cells exceed HBM (qwen3: 22.6 -> <16 GB)
        if spec.kind == "train":
            donate = (0, 1)       # params, opt_state
        elif spec.kind == "decode":
            donate = (1,)         # cache (params are reused every step)
        else:
            donate = ()
        with sharding_policy(mesh, rules):
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        text = compiled.as_text()
        coll = hlo_stats.collective_stats(text)
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_raw_scanned=float(cost.get("flops", 0.0)),
            bytes_raw_scanned=float(cost.get("bytes accessed", 0.0)),
            collectives_raw=coll,
            memory=_mem_dict(mem),
            hlo_ops=hlo_stats.hlo_op_histogram(text, 15),
        )
        try:
            if skip_probes:
                raise RuntimeError("probes skipped (--skip-probes)")
            probes = probe_costs(cfg, spec, mesh, rules_over=rules_over,
                                 constrain_grads=constrain_grads)
            rec["flops"] = probes["flops"]
            rec["bytes_accessed"] = probes["bytes_accessed"]
            rec["collectives"] = {
                k: probes[f"coll_{k}"]
                for k in hlo_stats.COLLECTIVES + ("total",)
            }
            rec["probe"] = {"seqs": probes["probe_seqs"],
                            "units": probes["probe_units"],
                            "raw": probes.get("probe_raw")}
        except Exception as e:  # probes are best-effort
            rec["probe_error"] = f"{type(e).__name__}: {e}"
            # fall back to the single-pod sibling's probe numbers, scaled to
            # this mesh's per-device share (global work is mesh-invariant)
            sib = RESULTS / f"{arch}__{spec.name}__pod_16x16.json"
            scaled = False
            if sib.exists():
                sr = json.loads(sib.read_text())
                if sr.get("ok") and "probe" in sr:
                    f = sr["n_chips"] / rec["n_chips"]
                    rec["flops"] = sr["flops"] * f
                    rec["bytes_accessed"] = sr["bytes_accessed"] * f
                    rec["collectives"] = {k: v * f for k, v in
                                          sr["collectives"].items()}
                    rec["probe_scaled_from"] = sib.name
                    scaled = True
            if not scaled:
                rec["flops"] = rec["flops_raw_scanned"]
                rec["bytes_accessed"] = rec["bytes_raw_scanned"]
                rec["collectives"] = coll
        if verbose:
            print(compiled.memory_analysis())
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed")})
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {arch:22s} {spec.name:12s} {mesh_name:16s} "
          f"{rec['total_s']:7.1f}s"
          + ("" if rec["ok"] else f"  {rec.get('error', '')[:120]}"))
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "peak_memory_in_bytes",
              "generated_code_size_in_bytes", "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["both", "single", "multi"])
    ap.add_argument("--rules", default="baseline",
                    help="sharding preset (see launch/shardings.PRESETS)")
    ap.add_argument("--constrain-grads", action="store_true",
                    help="pin grad shardings to param shardings (hillclimb)")
    ap.add_argument("--cast-once", action="store_true",
                    help="bf16 param cast before the layer scan (hillclimb)")
    ap.add_argument("--skip-probes", action="store_true",
                    help="compile-only (reuse single-pod sibling costs)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    meshes = {"both": [False, True], "single": [False],
              "multi": [True]}[args.mesh]
    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        for spec in shapes_for(cfg):
            if args.shape != "all" and spec.name not in args.shape.split(","):
                continue
            for mp in meshes:
                rec = run_cell(arch, spec, mp, verbose=args.verbose,
                               rules_name=args.rules,
                               constrain_grads=args.constrain_grads,
                               cast_once=args.cast_once,
                               skip_probes=args.skip_probes)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
