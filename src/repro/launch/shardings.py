"""Sharding trees for the launcher/dry-run: params, optimizer state, batches
and decode caches, derived from logical axes + the active policy rules."""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import policy as pol

# Named rule presets (hillclimb levers, EXPERIMENTS.md §Perf):
#   baseline  — FSDP("embed"->data) + TP + SP (the paper-faithful default)
#   dp_wide   — no tensor parallelism: the model axis joins the batch
#               (right for small archs where TP fragments tiny matmuls)
#   no_sp     — disable sequence-parallel residuals (trades memory for
#               fewer activation collectives)
#   tp_seq    — TP + sequence sharding of long KV (serving, long context)
PRESETS: dict[str, dict] = {
    "baseline": {},
    "dp_wide": {
        "batch": ("pod", "data", "model"),
        "heads": None, "kv_heads": None, "mlp": None, "vocab": None,
        "experts": None, "inner": None, "act_seq": None, "kv_seq": None,
        "embed": ("data", "model"),
    },
    "no_sp": {"act_seq": None},
    "tp_seq": {"embed": None},
}

# rules overrides per phase
TRAIN_RULES: dict = {}   # defaults: FSDP ("embed"->data) + TP + SP
# Serving inherits FSDP weight sharding: replicating weights across the
# data axis does not fit the big archs (dbrx f32 params = 33 GB/chip when
# only model-sharded). The per-step weight all-gathers this implies on the
# decode path are a measured baseline cost — see §Perf (bf16 weight
# gathers / weight-stationary serving are the hillclimb).
SERVE_RULES: dict = {}


def param_shardings(mesh: Mesh, api, rules: dict | None = None):
    axes = api.param_axes()
    ab = api.abstract_params()
    return jax.tree_util.tree_map(
        lambda ax, a: pol.param_sharding(mesh, ax, a.shape, rules),
        axes, ab,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def batch_shardings(mesh: Mesh, batch_specs: dict, rules=None):
    out = {}
    for k, v in batch_specs.items():
        ax = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = pol.param_sharding(mesh, tuple(ax), v.shape, rules)
    return out


_CACHE_AXES = {
    ("k", 5): ("layers", "batch", "kv_seq", "kv_heads", None),
    ("v", 5): ("layers", "batch", "kv_seq", "kv_heads", None),
    ("xk", 5): ("layers", "batch", None, "kv_heads", None),
    ("xv", 5): ("layers", "batch", None, "kv_heads", None),
    ("k", 6): ("layers", "layers", "batch", "kv_seq", "kv_heads", None),
    ("v", 6): ("layers", "layers", "batch", "kv_seq", "kv_heads", None),
    ("conv", 4): ("layers", "batch", None, "inner"),
    ("conv", 5): ("layers", "layers", "batch", None, "inner"),
    ("ssm", 5): ("layers", "batch", "inner", None, None),
    ("ssm", 6): ("layers", "layers", "batch", "inner", None, None),
}


def cache_shardings(mesh: Mesh, cache_ab, rules=None):
    def leaf(path, a):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        ax = _CACHE_AXES.get((name, len(a.shape)))
        if ax is None:
            ax = ("layers", "batch") + (None,) * (len(a.shape) - 2)
        return pol.param_sharding(mesh, ax, a.shape, rules)

    return jax.tree_util.tree_map_with_path(leaf, cache_ab)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def opt_shardings(mesh: Mesh, params_sh, opt_state_ab):
    """AdamState(step, m, v): step replicated; m/v mirror params."""
    from repro.train.optim import AdamState
    return AdamState(step=replicated(mesh), m=params_sh, v=params_sh)
