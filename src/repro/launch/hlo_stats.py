"""HLO text statistics: collective-traffic extraction for the roofline.

``cost_analysis()`` has no collective-bytes entry, so we parse the
post-SPMD HLO (``compiled.as_text()``). Operands are referenced by name
(no inline shapes), so we read each collective's RESULT shape(s) and
convert to *operand* bytes using the replica-group size:

    all-reduce / all-to-all / collective-permute: operand == result
    all-gather:     operand = result / group_size
    reduce-scatter: operand = result × group_size

Caveat (documented in EXPERIMENTS.md): collectives inside rolled
``while`` loops (scan-over-layers) appear once; the dry-run corrects via
depth-probe extrapolation, not by trip-count parsing.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind (full-program totals, i.e.
    bytes × participating shards)."""
    out: dict = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if m.group("variant") == "-done":
            continue
        kind = m.group("kind")
        count += 1
        result_bytes = sum(
            shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group("result"))
        )
        g = _GROUPS_RE.search(line)
        gsize = int(g.group(2)) if g else 1
        if kind == "all-gather":
            operand = result_bytes // max(gsize, 1)
        elif kind == "reduce-scatter":
            operand = result_bytes * gsize
        else:
            operand = result_bytes
        # result shape is per-shard; total traffic scales with shard count —
        # we report per-shard operand bytes summed over ops; the roofline
        # divides by per-chip link bandwidth, so per-shard is the right unit.
        out[kind] += operand
    out["total"] = sum(out[k] for k in COLLECTIVES if k in out)
    out["count"] = count
    return dict(out)


def hlo_op_histogram(hlo_text: str, top: int = 25) -> list[tuple[str, int]]:
    """Instruction-name histogram (quick look at what dominates the HLO)."""
    ops: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*([a-z-]+)\(",
            line)
        if m:
            ops[m.group(1)] += 1
    return sorted(ops.items(), key=lambda kv: -kv[1])[:top]
