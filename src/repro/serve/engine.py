"""Batched serving engine: prefill + decode with a slot-based batch
(continuous-batching-lite: finished sequences free their slot for the next
queued request at the following decode step).

Greedy decoding (argmax) keeps the engine deterministic for tests; the
sampling hook takes (logits, step) -> token ids.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, api: ModelApi, max_len: int = 256,
                 batch_slots: int = 4, eos_id: int | None = None,
                 sampler: Callable | None = None):
        self.api = api
        self.max_len = max_len
        self.slots = batch_slots
        self.eos = eos_id
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self._decode = jax.jit(api.decode)

    def run(self, requests: list[Request],
            extra_batch: dict | None = None) -> list[Request]:
        """Serve all requests (same prompt length per wave for simplicity of
        the batched prefill; production would bucket by length)."""
        queue = list(requests)
        while queue:
            wave = queue[: self.slots]
            queue = queue[self.slots:]
            self._run_wave(wave, extra_batch or {})
        return requests

    def _run_wave(self, wave: list[Request], extra_batch: dict) -> None:
        B = len(wave)
        S = len(wave[0].prompt)
        assert all(len(r.prompt) == S for r in wave), "bucket by length"
        tokens = jnp.asarray(np.stack([r.prompt for r in wave]), jnp.int32)
        batch = {"tokens": tokens, **extra_batch}
        logits, cache = self.api.prefill(params=self._params, batch=batch,
                                         max_len=self.max_len)
        vis = getattr(self.api.cfg, "n_vis_tokens", 0) \
            if self.api.cfg.family == "vlm" else 0
        pos = S + vis
        next_tok = self.sampler(logits[:, -1])
        for i, r in enumerate(wave):
            r.out.append(int(next_tok[i]))
        active = np.ones(B, bool)
        max_new = max(r.max_new_tokens for r in wave)
        for step in range(1, max_new):
            logits, cache = self._decode(
                self._params, cache, next_tok[:, None].astype(jnp.int32),
                jnp.asarray(pos, jnp.int32))
            pos += 1
            next_tok = self.sampler(logits[:, -1])
            for i, r in enumerate(wave):
                if not active[i]:
                    continue
                if len(r.out) >= r.max_new_tokens:
                    active[i] = False
                    r.done = True
                    continue
                t = int(next_tok[i])
                r.out.append(t)
                if self.eos is not None and t == self.eos:
                    active[i] = False
                    r.done = True
            if not active.any():
                break
        for r in wave:
            r.done = True

    def load(self, params) -> None:
        self._params = params
