"""Logical-axis sharding policy (MaxText-style rules).

Model code annotates tensors with *logical* axis names; the active policy
maps them to mesh axes. Outside a mesh context annotations are no-ops, so
the same model runs in CPU smoke tests (1 device) and the 512-chip dry-run.

Mesh axes:
  pod    — DCN axis between pods (multi-pod only)
  data   — DP batch + FSDP weight sharding
  model  — TP / EP / SP

Default rules:
  batch      -> ("pod", "data")       activations' batch dim
  embed      -> "data"  (weights: FSDP)   / None (activations)
  heads      -> "model"               attention heads (TP)
  kv_heads   -> "model" when divisible, else None
  mlp        -> "model"               FFN hidden (TP)
  experts    -> "model"               MoE expert dim (EP)
  vocab      -> "model"               embedding/unembedding (TP)
  seq        -> None (train)  / "model" (long-context KV: SP)
  layers     -> None                  scan dim
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "embed_act": None,
    "heads": "model",
    "kv_heads": "model",
    "q_group": None,
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_cap": "data",
    "expert_mlp": None,
    "vocab": "model",
    "seq": None,
    "act_seq": "model",   # sequence-parallel residual stream (train)
    "kv_seq": "model",
    "state": None,
    "conv": None,
    "layers": None,
    "inner": "model",
}


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def sharding_policy(mesh: Mesh, rules: dict | None = None):
    """Activate a mesh + logical rules for model annotations."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mesh axes that don't exist (e.g. "pod" on a single-pod mesh)
    axes = set(mesh.axis_names)

    def resolve(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in axes else None
        got = tuple(a for a in v if a in axes)
        return got if got else None

    resolved = {k: resolve(v) for k, v in merged.items()}
    prev = _current()
    _state.ctx = (mesh, resolved)
    try:
        yield
    finally:
        _state.ctx = prev


def spec_for(*logical: str | None) -> P:
    """PartitionSpec for a tuple of logical axis names (None = replicated)."""
    ctx = _current()
    if ctx is None:
        return P(*([None] * len(logical)))
    _, rules = ctx
    out, used = [], set()
    for name in logical:
        r = None if name is None else rules.get(name)
        if isinstance(r, tuple):
            r = tuple(a for a in r if a not in used) or None
        if isinstance(r, str) and r in used:
            r = None
        if r is not None:
            used.update(r if isinstance(r, tuple) else (r,))
        out.append(r)
    return P(*out)


def shard_count(logical: str) -> int:
    """Number of shards the active policy assigns to a logical axis
    (1 outside a mesh context)."""
    ctx = _current()
    if ctx is None:
        return 1
    mesh, rules = ctx
    r = rules.get(logical)
    if r is None:
        return 1
    size = 1
    for a in (r if isinstance(r, tuple) else (r,)):
        size *= mesh.shape[a]
    return size


def shard_as(x, *logical: str | None):
    """Annotate activation x with logical axes (no-op without a mesh)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = spec_for(*logical)
    # divisibility guard: replicate axes that don't divide evenly
    fixed = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            fixed.append(None)
            continue
        size = 1
        for a in (s if isinstance(s, tuple) else (s,)):
            size *= mesh.shape[a]
        fixed.append(s if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed))
    )


def named_sharding(mesh: Mesh, *logical: str | None) -> NamedSharding:
    with sharding_policy(mesh):
        spec = spec_for(*logical)
        fixed = spec
    return NamedSharding(mesh, fixed)


def param_sharding(mesh: Mesh, path: tuple[str, ...], shape: tuple[int, ...],
                   rules: dict | None = None) -> NamedSharding:
    """Sharding for a parameter given its logical axes annotation."""
    with sharding_policy(mesh, rules):
        spec = spec_for(*path)
        # divisibility guard
        fixed = []
        for dim, s in zip(shape, spec):
            if s is None:
                fixed.append(None)
                continue
            size = 1
            for a in (s if isinstance(s, tuple) else (s,)):
                size *= mesh.shape[a]
            fixed.append(s if dim % size == 0 else None)
    return NamedSharding(mesh, P(*fixed))
