"""Architecture registry: name -> uniform model API.

Every assigned architecture exposes the same surface so the launcher,
dry-run, trainer and server are arch-agnostic:

    api = get_model(cfg)
    api.init(key) / api.abstract_params() / api.param_axes()
    api.forward(params, batch)            -> (logits, aux)   # train path
    api.prefill(params, batch, max_len)   -> (logits, cache)
    api.decode(params, cache, tokens, pos)-> (logits, cache)
    api.input_specs(shape)                -> {name: ShapeDtypeStruct}
    api.count_params() / api.active_params()
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm, whisper
from repro.models.lm import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = [
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
]


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable
    abstract_params: Callable
    param_axes: Callable
    forward: Callable          # (params, batch) -> (logits, aux)
    forward_hidden: Callable   # (params, batch) -> (hidden, aux)
    unembed: Callable          # params -> [D, V]
    prefill: Callable          # (params, batch, max_len) -> (logits, cache)
    decode: Callable           # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Callable       # (batch, max_len) -> cache
    input_specs: Callable      # (ShapeSpec) -> {name: ShapeDtypeStruct}

    def count_params(self) -> int:
        ab = self.abstract_params()
        return sum(int(np.prod(a.shape))
                   for a in jax.tree_util.tree_leaves(ab))

    def active_params(self) -> int:
        """Per-token active parameters (MoE: only top-k experts)."""
        cfg = self.cfg
        total = self.count_params()
        if cfg.family != "moe" or not cfg.n_experts:
            return total
        expert = 3 * cfg.d_model * cfg.d_ff  # gate/up/down per expert
        inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert
        return total - inactive


def _vis_frames(cfg, spec: ShapeSpec) -> int:
    if cfg.family == "encdec":
        # stub frontend: frames after the conv stack; scale with tokens but
        # cap at whisper's 30 s window equivalent
        return min(1500, max(128, spec.seq_len // 2))
    return cfg.n_vis_tokens


def _lm_api(cfg: ModelConfig) -> ModelApi:
    def forward(params, batch, return_hidden=False):
        if cfg.family == "vlm":
            return lm.forward(cfg, params, batch["tokens"],
                              vis_embeds=batch["vis_embeds"],
                              return_hidden=return_hidden)
        return lm.forward(cfg, params, batch["tokens"],
                          return_hidden=return_hidden)

    def prefill(params, batch, max_len):
        if cfg.family == "vlm":
            return lm.prefill(cfg, params, batch["tokens"], max_len,
                              vis_embeds=batch["vis_embeds"])
        return lm.prefill(cfg, params, batch["tokens"], max_len)

    def input_specs(spec: ShapeSpec):
        B, S = spec.global_batch, spec.seq_len
        i32 = jnp.int32
        if spec.kind == "train":
            out = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                   "labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                out["vis_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
            return out
        if spec.kind == "prefill":
            out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                out["vis_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
            return out
        # decode: one new token against a cache of seq_len
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    return ModelApi(
        cfg=cfg,
        init=lambda key: lm.init_params(cfg, key),
        abstract_params=lambda: lm.abstract_params(cfg),
        param_axes=lambda: lm.param_axes(cfg),
        forward=forward,
        forward_hidden=lambda params, batch: forward(params, batch,
                                                     return_hidden=True),
        unembed=lambda params: lm.unembed_matrix(cfg, params),
        prefill=prefill,
        decode=lambda params, cache, tokens, pos: lm.decode_step(
            cfg, params, cache, tokens, pos),
        init_cache=lambda batch, max_len: lm.init_cache(cfg, batch, max_len),
        input_specs=input_specs,
    )


def _whisper_api(cfg: ModelConfig) -> ModelApi:
    def forward(params, batch, return_hidden=False):
        return whisper.forward(cfg, params, batch["tokens"],
                               batch["frames"], return_hidden=return_hidden)

    def prefill(params, batch, max_len):
        return whisper.prefill(cfg, params, batch["tokens"], batch["frames"],
                               max_len)

    def input_specs(spec: ShapeSpec):
        B, S = spec.global_batch, spec.seq_len
        nf = _vis_frames(cfg, spec)
        i32 = jnp.int32
        if spec.kind == "train":
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
                "frames": jax.ShapeDtypeStruct((B, nf, cfg.d_model),
                                               jnp.float32),
            }
        if spec.kind == "prefill":
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "frames": jax.ShapeDtypeStruct((B, nf, cfg.d_model),
                                               jnp.float32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    return ModelApi(
        cfg=cfg,
        init=lambda key: whisper.init_params(cfg, key),
        abstract_params=lambda: whisper.abstract_params(cfg),
        param_axes=lambda: whisper.param_axes(cfg),
        forward=forward,
        forward_hidden=lambda params, batch: forward(params, batch,
                                                     return_hidden=True),
        unembed=lambda params: whisper.unembed_matrix(cfg, params),
        prefill=prefill,
        decode=lambda params, cache, tokens, pos: whisper.decode_step(
            cfg, params, cache, tokens, pos),
        init_cache=lambda batch, max_len: whisper.init_cache(
            cfg, batch, max_len, n_frames=1500),
        input_specs=input_specs,
    )


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "encdec":
        return _whisper_api(cfg)
    return _lm_api(cfg)


# ---- config registry -------------------------------------------------------
_CONFIGS: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _CONFIGS[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _CONFIGS:
        _load_all()
    return _CONFIGS[name]()


def list_archs() -> list[str]:
    _load_all()
    return sorted(_CONFIGS)


def _load_all():
    import importlib
    import pkgutil
    import repro.configs as pkg

    for m in pkgutil.iter_modules(pkg.__path__):
        importlib.import_module(f"repro.configs.{m.name}")


def shapes_for(cfg: ModelConfig) -> list[ShapeSpec]:
    """The assigned shape cells applicable to this arch (long_500k only for
    sub-quadratic families — skip documented in DESIGN.md)."""
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return out
