from repro.models.lm import ModelConfig  # noqa: F401
from repro.models.registry import (  # noqa: F401
    ModelApi,
    ShapeSpec,
    get_config,
    get_model,
    list_archs,
    shapes_for,
)
