"""Model building blocks — pure functions over parameter pytrees.

Conventions:
  * params are nested dicts of f32 arrays; compute casts to ``cfg.dtype``;
  * every parameter has *logical axes* (see ``repro.sharding.policy``)
    declared in a parallel ``ParamSpec`` tree, from which the launcher
    derives NamedShardings (FSDP on "embed", TP on "heads"/"mlp"/"vocab",
    EP on "experts");
  * stacked-layer params carry a leading "layers" axis and are consumed by
    ``jax.lax.scan`` (compile time stays flat in depth — required for the
    94-layer MoE dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.policy import shard_as

# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"   # normal | zeros | ones | small
    scale: float = 0.02


def build_params(key, specs) -> Any:
    flat, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, s in zip(keys, flat):
        if s.init == "zeros":
            leaves.append(jnp.zeros(s.shape, jnp.float32))
        elif s.init == "ones":
            leaves.append(jnp.ones(s.shape, jnp.float32))
        elif s.init == "small":
            leaves.append(jax.random.normal(k, s.shape, jnp.float32)
                          * (s.scale / math.sqrt(max(s.shape[-1], 1))))
        else:
            leaves.append(jax.random.normal(k, s.shape, jnp.float32) * s.scale)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def spec_axes(specs) -> Any:
    """Same-structure tree of logical-axes tuples."""
    return jax.tree_util.tree_map(
        lambda s: s.axes, specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def abstract_params(specs) -> Any:
    """ShapeDtypeStructs for dry-run lowering (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# --------------------------------------------------------------------------
# norms & activations
# --------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * weight + bias).astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, n_heads, head_dim]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, grouped einsums — repeated KV is never materialized)
# --------------------------------------------------------------------------
def attn_specs(d_model: int, n_heads: int, n_kv: int, head_dim: int,
               qkv_bias: bool = False) -> dict:
    s = {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", None)),
        "wk": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", None)),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", None, "embed")),
    }
    if qkv_bias:
        s["bq"] = ParamSpec((n_heads, head_dim), ("heads", None), "zeros")
        s["bk"] = ParamSpec((n_kv, head_dim), ("kv_heads", None), "zeros")
        s["bv"] = ParamSpec((n_kv, head_dim), ("kv_heads", None), "zeros")
    return s


def qkv_proj(p, x, n_heads: int, n_kv: int, rope_theta: float | None,
             positions):
    """x: [B,S,D] -> q [B,S,H,hd], k/v [B,S,K,hd] (+bias, +RoPE)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def gqa_attend(q, k, v, mask, *, softmax_in_f32: bool = True):
    """Grouped-query attention core.

    q: [B,S,H,hd], k/v: [B,T,K,hd] with H = K·G. mask: broadcastable to
    [B,1,1,S,T] (True = attend). Returns [B,S,H,hd].
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) * scale  # [B,K,G,S,T]
    if softmax_in_f32:
        scores = scores.astype(jnp.float32)
    # sharding fallback for head counts not divisible by the model axis:
    # shard the query-sequence dim of the score tensor instead
    scores = shard_as(scores, "batch", "kv_heads", None, "act_seq", None)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


def causal_mask(S: int, T: int, offset: int = 0):
    """True where query i (at absolute pos offset+i) may attend key j."""
    i = jnp.arange(S)[:, None] + offset
    j = jnp.arange(T)[None, :]
    return (j <= i)[None, None, None]


def blockwise_gqa_attend(q, k, v, *, causal: bool, block_q: int = 1024,
                         block_k: int = 2048):
    """Memory-bounded attention: scan over query blocks, inner scan over KV
    blocks with online softmax (flash-attention dataflow expressed in XLA).
    Peak live score tile is [B,K,G,BQ,BK] instead of [B,K,G,S,T] — this is
    what makes the 32k-prefill cells fit. Same math as ``gqa_attend``.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    K = k.shape[2]
    G = H // K

    def _divisor_block(n, target):
        for d in range(min(target, n), 0, -1):
            if n % d == 0:
                return d
        return n

    bq = _divisor_block(S, block_q)
    bk = _divisor_block(T, block_k)
    scale = 1.0 / math.sqrt(hd)
    qb = q.reshape(B, S // bq, bq, K, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, T // bk, bk, K, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, T // bk, bk, K, hd).transpose(1, 0, 3, 2, 4)

    def q_block(qi, q_i):
        # q_i: [B,K,G,bq,hd]
        def kv_step(carry, inp):
            kj, k_j, v_j = inp
            acc, m, l = carry
            s = jnp.einsum("bkgqd,bktd->bkgqt", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            if causal:
                rows = qi * bq + jnp.arange(bq)[:, None]
                cols = kj * bk + jnp.arange(bk)[None, :]
                s = jnp.where((cols <= rows)[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * corr + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, v_j.astype(jnp.float32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, K, G, bq, hd), jnp.float32)
        m0 = jnp.full((B, K, G, bq, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq, 1), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(T // bk), kb, vb))
        return acc / jnp.maximum(l, 1e-30)

    out = jax.lax.map(lambda iq: q_block(iq[0], iq[1]),
                      (jnp.arange(S // bq), qb))
    # [nq,B,K,G,bq,hd] -> [B,S,H,hd]
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, K, G, S, hd)
    return out.reshape(B, H, S, hd).swapaxes(1, 2).astype(q.dtype)


def attention(p, x, cfg, positions, mask=None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = qkv_proj(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.rope_theta,
                       positions)
    q = shard_as(q, "batch", "seq", "heads", None)
    k = shard_as(k, "batch", "seq", "kv_heads", None)
    v = shard_as(v, "batch", "seq", "kv_heads", None)
    S = x.shape[1]
    if mask is None:
        mask = causal_mask(S, S) if cfg.causal else jnp.ones(
            (1, 1, 1, S, S), bool)
    out = gqa_attend(q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard_as(out, "batch", "seq", "embed_act"), (k, v)


def attention_decode(p, x, cfg, cache_k, cache_v, pos):
    """Single-token decode against a KV cache.

    x: [B,1,D]; cache_k/v: [B,T,K,hd] (ring buffer, absolute positions);
    pos: [] int32 current position. Returns (out, (new_k, new_v)).
    """
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k_new, v_new = qkv_proj(p, x, cfg.n_heads, cfg.n_kv_heads,
                               cfg.rope_theta, positions)
    T = cache_k.shape[1]
    slot = pos % T
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
    cache_k = shard_as(cache_k, "batch", "kv_seq", "kv_heads", None)
    cache_v = shard_as(cache_v, "batch", "kv_seq", "kv_heads", None)
    valid = (jnp.arange(T) <= pos)[None, None, None, None, :]  # [1,1,1,1,T]
    out = gqa_attend(q, cache_k, cache_v, valid)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (cache_k, cache_v)


def cross_attention(p, x, kv_cached, mask=None):
    """Encoder-decoder cross attention (whisper). kv_cached = (k, v) from
    the encoder output projections; no RoPE."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    k, v = kv_cached
    S, T = q.shape[1], k.shape[1]
    if mask is None:
        mask = jnp.ones((1, 1, 1, S, T), bool)
    out = gqa_attend(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def cross_kv(p, enc_out):
    dt = enc_out.dtype
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp_specs(d_model: int, d_ff: int, act: str = "swiglu") -> dict:
    if act == "swiglu":
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), "small"),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), "small"),
            "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), "small"),
        }
    return {  # gelu (whisper/stablelm-style 2-layer)
        "w_in": ParamSpec((d_model, d_ff), ("embed", "mlp"), "small"),
        "b_in": ParamSpec((d_ff,), ("mlp",), "zeros"),
        "w_out": ParamSpec((d_ff, d_model), ("mlp", "embed"), "small"),
        "b_out": ParamSpec((d_model,), ("embed",), "zeros"),
    }


def mlp(p, x, act: str = "swiglu"):
    dt = x.dtype
    if act == "swiglu":
        h = silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
        h = shard_as(h, "batch", "seq", "mlp")
        return h @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_in"].astype(dt) + p["b_in"].astype(dt))
    h = shard_as(h, "batch", "seq", "mlp")
    return h @ p["w_out"].astype(dt) + p["b_out"].astype(dt)


# --------------------------------------------------------------------------
# Mixture of Experts (top-k router, dense one-hot dispatch, EP-shardable)
# --------------------------------------------------------------------------
def moe_specs(d_model: int, d_ff: int, n_experts: int) -> dict:
    return {
        "router": ParamSpec((d_model, n_experts), ("embed", "experts")),
        "w_gate": ParamSpec((n_experts, d_model, d_ff),
                            ("experts", "embed", "expert_mlp"), "small"),
        "w_up": ParamSpec((n_experts, d_model, d_ff),
                          ("experts", "embed", "expert_mlp"), "small"),
        "w_down": ParamSpec((n_experts, d_ff, d_model),
                            ("experts", "expert_mlp", "embed"), "small"),
    }


def moe_ffn(p, x, n_experts: int, top_k: int, capacity_factor: float = 1.25):
    """Token-choice top-k MoE with SHARD-LOCAL sort-based capacity dispatch.

    Tokens are routed to their top-k experts by *gather/scatter* (zero
    matmul FLOPs — the compiled FLOP count stays ≈ active-expert compute,
    unlike one-hot-matmul dispatch which inflates it by E/k).

    SPMD shape: the token dim is pre-split into G groups matching the
    batch sharding, and ALL index ops (sort, gather, scatter) are batched
    over that sharded leading dim — dispatch is shard-local (no token
    exchange across the DP axis; cross-chip traffic is only the EP
    dimension of the expert einsums). A global argsort makes XLA
    all-gather the whole token array per layer (measured: TBs/step).

    Per-expert capacity C = ceil(top_k·T_local/E · cap_factor); overflow
    tokens are dropped for that expert (Switch/GShard semantics).

    Returns (out, aux_loss).
    """
    from repro.sharding.policy import shard_count

    dt = x.dtype
    B, S, D = x.shape
    T = B * S
    E, K = n_experts, top_k
    G = shard_count("batch")
    if T % G:
        G = 1
    Tl = T // G
    C = max(int(math.ceil(K * Tl / E * capacity_factor)), K)

    xf = x.reshape(G, Tl, D)
    xf = shard_as(xf, "batch", None, "embed_act")
    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)   # [G,Tl,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, K)                     # [G,Tl,K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- sort (token, k)-slots by expert id, per group ----------------
    expert_flat = top_idx.reshape(G, Tl * K)
    order = jnp.argsort(expert_flat, axis=-1, stable=True)       # [G,Tl*K]
    tok_sorted = jnp.take_along_axis(
        jnp.broadcast_to(jnp.arange(Tl * K) // K, (G, Tl * K)), order, -1)
    exp_sorted = jnp.take_along_axis(expert_flat, order, -1)
    gate_sorted = jnp.take_along_axis(top_p.reshape(G, Tl * K), order, -1)
    # position of each slot within its expert's run
    ar = jnp.arange(Tl * K)[None, :]
    seg_start = jax.vmap(
        lambda e: jnp.searchsorted(e, jnp.arange(E), side="left"))(exp_sorted)
    pos_in_e = ar - jnp.take_along_axis(seg_start, exp_sorted, -1)
    keep = pos_in_e < C                                          # capacity

    # ---- gather tokens to [G, E, C, D] --------------------------------
    slot = jnp.where(keep, exp_sorted * C + pos_in_e, E * C)     # E*C: trash

    def fill(val, dtype):
        buf = jnp.zeros((G, E * C + 1), dtype)
        return buf.at[jnp.arange(G)[:, None], slot].set(
            val.astype(dtype), mode="drop")[:, : E * C].reshape(G, E, C)

    src_tok = fill(tok_sorted, jnp.int32)
    src_gate = fill(jnp.where(keep, gate_sorted, 0.0), jnp.float32)
    src_valid = fill(keep, jnp.float32)

    xe = jnp.take_along_axis(
        xf, src_tok.reshape(G, E * C)[..., None], axis=1
    ).reshape(G, E, C, D)
    xe = xe * src_valid[..., None].astype(dt)
    xe = shard_as(xe, "batch", "experts", None, "embed_act")

    # ---- expert FFN (EP: experts sharded, contraction local) ----------
    h = silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    out_e = out_e * src_gate[..., None].astype(dt)

    # ---- combine: scatter-add back to tokens, per group ----------------
    out = jnp.zeros((G, Tl, D), dt).at[
        jnp.arange(G)[:, None], src_tok.reshape(G, E * C)
    ].add(out_e.reshape(G, E * C, D))
    out = out.reshape(B, S, D)
    out = shard_as(out, "batch", "act_seq", "embed_act")

    # load-balancing aux loss (Switch-style, global mean)
    me = probs.mean(axis=(0, 1))                                 # [E]
    ce = jax.nn.one_hot(top_idx, E, dtype=jnp.float32).sum(2).mean((0, 1))
    aux = E * jnp.sum(me * ce / K)
    return out, aux.astype(jnp.float32)


# --------------------------------------------------------------------------
# Mamba2 / SSD (state-space duality, arXiv:2405.21060) — chunked scan
# --------------------------------------------------------------------------
def mamba2_specs(d_model: int, d_state: int, head_dim: int = 64,
                 expand: int = 2, d_conv: int = 4) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return {
        "in_proj": ParamSpec(
            (d_model, 2 * d_inner + 2 * d_state + n_heads),
            ("embed", "inner"), "small"),
        "conv_w": ParamSpec((d_conv, conv_dim), (None, "inner")),
        "conv_b": ParamSpec((conv_dim,), ("inner",), "zeros"),
        "A_log": ParamSpec((n_heads,), ("inner",), "zeros"),
        "D": ParamSpec((n_heads,), ("inner",), "ones"),
        "dt_bias": ParamSpec((n_heads,), ("inner",), "zeros"),
        "norm_w": ParamSpec((d_inner,), ("inner",), "ones"),
        "out_proj": ParamSpec((d_inner, d_model), ("inner", "embed"), "small"),
    }


def _ssd_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: [B,S,C], w: [k,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def mamba2_forward(p, x, cfg, chunk: int = 128, return_state: bool = False):
    """SSD block, full sequence. x: [B,S,D] -> [B,S,D].

    Chunked algorithm: intra-chunk 'attention form' + inter-chunk state
    recurrence (scan over chunks) — the TPU-friendly formulation the Pallas
    ``ssd_scan`` kernel tiles into VMEM. With ``return_state`` also returns
    ``(conv_state, ssm_state)`` for decode continuation.
    """
    dt_ = x.dtype
    B, S, D = x.shape
    d_inner, H = _ssd_dims(cfg)
    N = cfg.ssm_state
    P_ = cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    xbc_pre = xbc
    xbc = silu(causal_conv1d(xbc, p["conv_w"].astype(dt_),
                             p["conv_b"].astype(dt_)))
    xs, B_, C_ = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xs = shard_as(xs, "batch", "seq", "inner")

    # f32 SSM core
    xs = xs.reshape(B, S, H, P_).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # [H]
    B_ = B_.astype(jnp.float32)                                   # [B,S,N]
    C_ = C_.astype(jnp.float32)

    chunk = min(chunk, S)
    assert S % chunk == 0, f"seq {S} not divisible by ssd_chunk {chunk}"
    nc = S // chunk
    # scan over chunks: intra-chunk quadratic form + carried state. Only one
    # chunk's [B,q,q,H] decay tile is ever live (32k-seq cells stay bounded).
    xs_c = xs.reshape(B, nc, chunk, H, P_).swapaxes(0, 1)
    dt_c = dt.reshape(B, nc, chunk, H).swapaxes(0, 1)
    B_c = B_.reshape(B, nc, chunk, N).swapaxes(0, 1)
    C_c = C_.reshape(B, nc, chunk, N).swapaxes(0, 1)
    ii, jj = jnp.meshgrid(jnp.arange(chunk), jnp.arange(chunk),
                          indexing="ij")
    causal = (jj <= ii)[None, :, :, None]

    def chunk_step(h, inp):
        x_c, d_c, b_c, c_c = inp                                  # [B,q,...]
        dA = d_c * A[None, None, :]                               # [B,q,H]
        cum = jnp.cumsum(dA, axis=1)
        seg = cum[:, :, None, :] - cum[:, None, :, :]             # [B,i,j,H]
        Lmat = jnp.where(causal, jnp.exp(seg), 0.0)
        G = jnp.einsum("bin,bjn->bij", c_c, b_c)                  # [B,i,j]
        M = G[..., None] * Lmat * d_c[:, None, :, :]              # [B,i,j,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, x_c)
        y_inter = jnp.einsum("bin,bih,bhpn->bihp",
                             c_c, jnp.exp(cum), h)
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)              # [B,q,H]
        st = jnp.einsum("bqh,bqn,bqhp->bhpn",
                        d_c * decay_to_end, b_c, x_c)
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + st
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, P_, N), jnp.float32)
    h_last, y = jax.lax.scan(chunk_step, h0, (xs_c, dt_c, B_c, C_c),
                             unroll=getattr(cfg, "ssd_unroll", False))
    y = y.swapaxes(0, 1).reshape(B, S, H, P_)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs.reshape(
        B, S, H, P_)
    y = y.reshape(B, S, d_inner).astype(dt_)

    y = rms_norm(y * silu(z), p["norm_w"])
    out = y @ p["out_proj"].astype(dt_)
    if not return_state:
        return out, None
    k = p["conv_w"].shape[0]
    conv_state = xbc_pre[:, S - (k - 1):, :]
    return out, (conv_state, h_last)


def mamba2_decode(p, x, cfg, conv_state, ssm_state):
    """Single-token SSD recurrence. x: [B,1,D].

    conv_state: [B, d_conv-1, conv_dim]; ssm_state: [B,H,P,N].
    """
    dt_ = x.dtype
    B = x.shape[0]
    d_inner, H = _ssd_dims(cfg)
    N, P_ = cfg.ssm_state, cfg.ssm_head_dim

    zxbcdt = x[:, 0] @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)
    new_conv_state = window[:, 1:]
    w = p["conv_w"].astype(dt_)
    xbc = silu(jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(dt_))
    xs, B_, C_ = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    xs = xs.reshape(B, H, P_).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                                 # [B,H]
    B_ = B_.astype(jnp.float32)
    C_ = C_.astype(jnp.float32)

    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, B_, xs)
    new_ssm = ssm_state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C_, new_ssm)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(B, d_inner).astype(dt_)
    y = rms_norm(y * silu(z), p["norm_w"])
    return (y @ p["out_proj"].astype(dt_))[:, None, :], new_conv_state, new_ssm
