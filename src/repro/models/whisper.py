"""Whisper-style encoder-decoder (whisper-tiny backbone).

Per the assignment, the conv/audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings [B, S_frames, D] (what the two conv
layers would emit); a trained linear adapter maps them into the encoder.
Positions are sinusoidal (no learned table ⇒ any sequence length lowers).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.blocks import ParamSpec
from repro.models.lm import ModelConfig, _apply_norm, _norm_specs, stack_specs
from repro.sharding.policy import shard_as


def sinusoid_pos(S: int, D: int, offset=0):
    pos = (jnp.arange(S) + offset)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, D, 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, dim / D)
    pe = jnp.zeros((S, D), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def _enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": _norm_specs(cfg),
        "attn": B.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, cfg.qkv_bias),
        "ln2": _norm_specs(cfg),
        "mlp": B.mlp_specs(cfg.d_model, cfg.d_ff, cfg.act),
    }


def _dec_layer_specs(cfg: ModelConfig) -> dict:
    s = _enc_layer_specs(cfg)
    s["ln_x"] = _norm_specs(cfg)
    s["xattn"] = B.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, cfg.qkv_bias)
    return s


def model_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "frame_proj": ParamSpec((cfg.d_model, cfg.d_model),
                                ("embed", "embed_act"), "small"),
        "enc_layers": stack_specs(_enc_layer_specs(cfg), cfg.n_enc_layers),
        "enc_norm": _norm_specs(cfg),
        "dec_layers": stack_specs(_dec_layer_specs(cfg), cfg.n_layers),
        "final_norm": _norm_specs(cfg),
    }


def init_params(cfg, key):
    return B.build_params(key, model_specs(cfg))


def abstract_params(cfg):
    return B.abstract_params(model_specs(cfg))


def param_axes(cfg):
    return B.spec_axes(model_specs(cfg))


def _self_attn(cfg, p, pfx, x, positions, mask, causal=False):
    q, k, v = B.qkv_proj(p["attn"], x, cfg.n_heads, cfg.n_kv_heads, None,
                         positions)
    if x.shape[1] >= 8192:
        o = B.blockwise_gqa_attend(q, k, v, causal=causal)
    else:
        o = B.gqa_attend(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", o,
                      p["attn"]["wo"].astype(x.dtype)), (k, v)


def encode(cfg, params, frames):
    dt = cfg.dtype
    x = frames.astype(dt) @ params["frame_proj"].astype(dt)
    x = x + sinusoid_pos(x.shape[1], cfg.d_model).astype(dt)[None]
    x = shard_as(x, "batch", "act_seq", "embed_act")
    S = x.shape[1]
    full = jnp.ones((1, 1, 1, S, S), bool)
    positions = jnp.arange(S)[None, :]

    def layer(p_l, x):
        h = _apply_norm(cfg, p_l["ln1"], x)
        o, _ = _self_attn(cfg, p_l, "", h, positions, full)
        x = x + o
        h = _apply_norm(cfg, p_l["ln2"], x)
        return x + B.mlp(p_l["mlp"], h, cfg.act)

    fn = jax.checkpoint(layer) if cfg.remat else layer

    def body(x, p_l):
        return fn(p_l, x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=cfg.scan_unroll)
    return _apply_norm(cfg, params["enc_norm"], x)


def _dec_layer(cfg, p_l, x, enc_out, positions, self_mask):
    h = _apply_norm(cfg, p_l["ln1"], x)
    o, kv = _self_attn(cfg, p_l, "", h, positions, self_mask, causal=True)
    x = x + o
    h = _apply_norm(cfg, p_l["ln_x"], x)
    ckv = B.cross_kv(p_l["xattn"], enc_out)
    x = x + B.cross_attention(p_l["xattn"], h, ckv)
    h = _apply_norm(cfg, p_l["ln2"], x)
    return x + B.mlp(p_l["mlp"], h, cfg.act), kv, ckv


def unembed_matrix(cfg, params):
    return params["embed"].astype(cfg.dtype).T


def forward(cfg, params, tokens, frames, return_hidden=False):
    """Training forward. Returns (logits [B,St,V], aux=None)."""
    from repro.models.lm import cast_params
    params = cast_params(cfg, params)
    enc_out = encode(cfg, params, frames)
    dt = cfg.dtype
    y = params["embed"].astype(dt)[tokens]
    y = y + sinusoid_pos(y.shape[1], cfg.d_model).astype(dt)[None]
    y = shard_as(y, "batch", "act_seq", "embed_act")
    St = y.shape[1]
    positions = jnp.arange(St)[None, :]
    mask = B.causal_mask(St, St)

    def layer(p_l, y):
        y, _, _ = _dec_layer(cfg, p_l, y, enc_out, positions, mask)
        return y

    fn = jax.checkpoint(layer) if cfg.remat else layer

    def body(y, p_l):
        return fn(p_l, y), None

    y, _ = jax.lax.scan(body, y, params["dec_layers"],
                        unroll=cfg.scan_unroll)
    y = _apply_norm(cfg, params["final_norm"], y)
    if return_hidden:
        return y, None
    logits = y @ params["embed"].astype(dt).T
    return shard_as(logits, "batch", "seq", "vocab"), None


def init_cache(cfg, batch: int, max_len: int, n_frames: int, dtype=None):
    dtype = dtype or cfg.dtype
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch, max_len, K, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, K, hd), dtype),
        "xk": jnp.zeros((L, batch, n_frames, K, hd), dtype),
        "xv": jnp.zeros((L, batch, n_frames, K, hd), dtype),
    }


def prefill(cfg, params, tokens, frames, max_len: int):
    from repro.models.lm import cast_params
    params = cast_params(cfg, params)
    enc_out = encode(cfg, params, frames)
    dt = cfg.dtype
    y = params["embed"].astype(dt)[tokens]
    y = y + sinusoid_pos(y.shape[1], cfg.d_model).astype(dt)[None]
    St = y.shape[1]
    positions = jnp.arange(St)[None, :]
    mask = B.causal_mask(St, St)
    pad = max_len - St

    def body(y, p_l):
        y, kv, ckv = _dec_layer(cfg, p_l, y, enc_out, positions, mask)
        k = jnp.pad(kv[0], ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(kv[1], ((0, 0), (0, pad), (0, 0), (0, 0)))
        return y, (k, v, ckv[0], ckv[1])

    y, ys = jax.lax.scan(body, y, params["dec_layers"],
                         unroll=cfg.scan_unroll)
    y = _apply_norm(cfg, params["final_norm"], y[:, -1:])
    logits = y @ params["embed"].astype(dt).T
    return logits, {"k": ys[0], "v": ys[1], "xk": ys[2], "xv": ys[3]}


def decode_step(cfg, params, cache, tokens, pos):
    from repro.models.lm import cast_params
    params = cast_params(cfg, params)
    dt = cfg.dtype
    y = params["embed"].astype(dt)[tokens]
    y = y + sinusoid_pos(1, cfg.d_model, offset=pos).astype(dt)[None]

    def body(y, inp):
        p_l, k, v, xk, xv = inp
        h = _apply_norm(cfg, p_l["ln1"], y)
        q, k_new, v_new = B.qkv_proj(p_l["attn"], h, cfg.n_heads,
                                     cfg.n_kv_heads, None, None)
        T = k.shape[1]
        slot = pos % T
        k = jax.lax.dynamic_update_slice_in_dim(
            k, k_new.astype(k.dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            v, v_new.astype(v.dtype), slot, axis=1)
        valid = (jnp.arange(T) <= pos)[None, None, None, None, :]
        o = B.gqa_attend(q, k.astype(dt), v.astype(dt), valid)
        y = y + jnp.einsum("bshk,hkd->bsd", o, p_l["attn"]["wo"].astype(dt))
        h = _apply_norm(cfg, p_l["ln_x"], y)
        y = y + B.cross_attention(p_l["xattn"], h,
                                  (xk.astype(dt), xv.astype(dt)))
        h = _apply_norm(cfg, p_l["ln2"], y)
        y = y + B.mlp(p_l["mlp"], h, cfg.act)
        return y, (k, v)

    y, (k, v) = jax.lax.scan(
        body, y, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), unroll=cfg.scan_unroll)
    y = _apply_norm(cfg, params["final_norm"], y)
    logits = y @ params["embed"].astype(dt).T
    return logits, {"k": k, "v": v, "xk": cache["xk"], "xv": cache["xv"]}
