"""Unified decoder LM covering the dense / MoE / SSM / hybrid families.

One parameter layout, one forward, one decode path; the per-layer block is
selected by ``cfg.family``. Layers are *stacked* ([L, ...] leading axis) and
consumed by ``jax.lax.scan`` so compile time is depth-independent (the
94-layer qwen3-moe dry-run lowers in seconds). ``jax.checkpoint`` inside the
scan gives full-layer remat for training.

Families:
  dense  — pre-norm GQA attention + (SwiGLU|GELU) MLP
  moe    — attention + top-k MoE FFN (sort-based dispatch, EP-shardable)
  ssm    — Mamba2/SSD blocks (attention-free)
  hybrid — Zamba2-style: Mamba2 backbone with one *shared* attention+MLP
           block applied every ``hybrid_attn_every`` layers
  vlm    — dense backbone with a prepended (stubbed) patch-embedding prefix
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.blocks import ParamSpec
from repro.sharding.policy import shard_as


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    act: str = "swiglu"
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    qkv_bias: bool = False
    qk_norm: bool = False        # qwen3-style per-head q/k RMSNorm
    rope_theta: float | None = 10000.0
    causal: bool = True
    tie_embeddings: bool = True
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssd_chunk: int = 128
    # hybrid
    hybrid_attn_every: int = 0
    # encdec (whisper)
    n_enc_layers: int = 0
    # vlm
    n_vis_tokens: int = 0
    # execution
    dtype: Any = jnp.bfloat16
    remat: bool = True
    sub_quadratic: bool = False  # supports 500k-token decode
    # cost-probe mode (dry-run only): unroll scans so HLO FLOP counting is
    # exact — rolled `while` bodies are counted once by HloCostAnalysis
    scan_unroll: bool = False
    ssd_unroll: bool = False
    # §Perf lever: cast the sharded param tree to the compute dtype ONCE at
    # step entry, so FSDP all-gathers move bf16 instead of f32
    cast_once: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4) if self.family != "hybrid" else 6,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16 if self.head_dim else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            hybrid_attn_every=3 if self.hybrid_attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_vis_tokens=min(self.n_vis_tokens, 8),
            dtype=jnp.float32,
        )
        small.update(over)
        return dataclasses.replace(self, **small)


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------
def _norm_specs(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": ParamSpec((d,), ("embed",), "ones"),
                "b": ParamSpec((d,), ("embed",), "zeros")}
    return {"w": ParamSpec((d,), ("embed",), "ones")}


def _apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return B.layer_norm(x, p["w"], p["b"])
    return B.rms_norm(x, p["w"])


def _attn_specs(cfg):
    s = B.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                     cfg.qkv_bias)
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((cfg.hd,), (None,), "ones")
        s["k_norm"] = ParamSpec((cfg.hd,), (None,), "ones")
    return s


def layer_specs(cfg) -> dict:
    if cfg.family == "ssm":
        return {
            "norm": _norm_specs(cfg),
            "mamba": B.mamba2_specs(cfg.d_model, cfg.ssm_state,
                                    cfg.ssm_head_dim, cfg.ssm_expand,
                                    cfg.ssm_conv),
        }
    s = {
        "ln1": _norm_specs(cfg),
        "attn": _attn_specs(cfg),
        "ln2": _norm_specs(cfg),
    }
    if cfg.family == "moe":
        s["moe"] = B.moe_specs(cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        s["mlp"] = B.mlp_specs(cfg.d_model, cfg.d_ff, cfg.act)
    return s


def stack_specs(specs, n: int):
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def model_specs(cfg) -> dict:
    s: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "final_norm": _norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((cfg.d_model, cfg.vocab),
                                 ("embed", "vocab"), "small")
    if cfg.family == "hybrid":
        per = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // per
        ssm_cfg = dataclasses.replace(cfg, family="ssm")
        s["groups"] = stack_specs(
            stack_specs(layer_specs(ssm_cfg), per), n_groups)
        dense_cfg = dataclasses.replace(cfg, family="dense")
        s["shared"] = layer_specs(dense_cfg)   # ONE shared block
        rem = cfg.n_layers - n_groups * per
        if rem:
            s["tail"] = stack_specs(layer_specs(ssm_cfg), rem)
    else:
        s["layers"] = stack_specs(layer_specs(cfg), cfg.n_layers)
    if cfg.family == "vlm":
        # stubbed modality frontend: a trained projection of precomputed
        # patch embeddings into the LM's embedding space
        s["vis_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                  ("embed", "embed_act"), "small")
    return s


def init_params(cfg, key):
    return B.build_params(key, model_specs(cfg))


def abstract_params(cfg):
    return B.abstract_params(model_specs(cfg))


def param_axes(cfg):
    return B.spec_axes(model_specs(cfg))


# --------------------------------------------------------------------------
# blocks (single layer, unstacked params)
# --------------------------------------------------------------------------
def _maybe_qk_norm(cfg, p, q, k):
    if cfg.qk_norm:
        q = B.rms_norm(q, p["q_norm"])
        k = B.rms_norm(k, p["k_norm"])
    return q, k


def _attn_block(cfg, p, x, positions, mask=None):
    h = _apply_norm(cfg, p["ln1"], x)
    q, k, v = B.qkv_proj(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                         cfg.rope_theta, positions)
    q, k = _maybe_qk_norm(cfg, p["attn"], q, k)
    q = shard_as(q, "batch", "seq", "heads", None)
    S = x.shape[1]
    if S >= 8192 and mask is None:
        # long sequences: blockwise online-softmax (never materialize SxS)
        o = B.blockwise_gqa_attend(q, k, v, causal=cfg.causal)
    else:
        if mask is None:
            mask = B.causal_mask(S, S) if cfg.causal else jnp.ones(
                (1, 1, 1, S, S), bool)
        o = B.gqa_attend(q, k, v, mask)
    o = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
    x = x + shard_as(o, "batch", "act_seq", "embed_act")
    return x, (k, v)


def _attn_block_decode(cfg, p, x, cache_k, cache_v, pos):
    h = _apply_norm(cfg, p["ln1"], x)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k_new, v_new = B.qkv_proj(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.rope_theta, positions)
    q, k_new = _maybe_qk_norm(cfg, p["attn"], q, k_new)
    T = cache_k.shape[1]
    slot = pos % T
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    valid = (jnp.arange(T) <= pos)[None, None, None, None, :]
    o = B.gqa_attend(q, cache_k.astype(x.dtype), cache_v.astype(x.dtype),
                     valid)
    o = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
    return x + o, (cache_k, cache_v)


def _ffn_block(cfg, p, x):
    h = _apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        o, aux = B.moe_ffn(p["moe"], h, cfg.n_experts, cfg.top_k,
                           cfg.capacity_factor)
    else:
        o, aux = B.mlp(p["mlp"], h, cfg.act), None
    return x + shard_as(o, "batch", "act_seq", "embed_act"), aux


def dense_layer(cfg, p, x, positions, mask=None):
    x, kv = _attn_block(cfg, p, x, positions, mask)
    x, aux = _ffn_block(cfg, p, x)
    return x, kv, aux


def ssm_layer(cfg, p, x):
    h = _apply_norm(cfg, p["norm"], x)
    o, _ = B.mamba2_forward(p["mamba"], h, cfg, chunk=cfg.ssd_chunk)
    return x + shard_as(o, "batch", "act_seq", "embed_act")


def ssm_layer_prefill(cfg, p, x):
    h = _apply_norm(cfg, p["norm"], x)
    o, state = B.mamba2_forward(p["mamba"], h, cfg, chunk=cfg.ssd_chunk,
                                return_state=True)
    return x + o, state


def ssm_layer_decode(cfg, p, x, conv_state, ssm_state):
    h = _apply_norm(cfg, p["norm"], x)
    o, conv_state, ssm_state = B.mamba2_decode(p["mamba"], h, cfg,
                                               conv_state, ssm_state)
    return x + o, conv_state, ssm_state


# --------------------------------------------------------------------------
# full model: train-forward, prefill, decode
# --------------------------------------------------------------------------
def _embed(cfg, params, tokens, vis_embeds=None):
    x = params["embed"].astype(cfg.dtype)[tokens]
    if cfg.family == "vlm":
        assert vis_embeds is not None, "vlm needs patch embeddings"
        v = vis_embeds.astype(cfg.dtype) @ params["vis_proj"].astype(cfg.dtype)
        x = jnp.concatenate([v, x], axis=1)
    return shard_as(x, "batch", "act_seq", "embed_act")


def _logits(cfg, params, x):
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        w = params["embed"].astype(cfg.dtype).T
    else:
        w = params["unembed"].astype(cfg.dtype)
    logits = x @ w
    return shard_as(logits, "batch", "seq", "vocab")


def _scan_layers(cfg, layer_fn, x, stacked, collect=False):
    fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn

    def body(carry, p_l):
        x, aux = carry
        out = fn(p_l, x)
        x_new, extra, aux_l = out
        aux = aux + (aux_l if aux_l is not None else 0.0)
        return (x_new, aux), (extra if collect else None)

    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                stacked, unroll=cfg.scan_unroll)
    return x, aux, ys



def cast_params(cfg, params):
    """One cast of the (sharded) param tree to the compute dtype BEFORE the
    layer scan: XLA then all-gathers bf16, not f32 — halves FSDP gather
    traffic. The in-block .astype() calls become no-ops. Gated on
    cfg.cast_once so the §Perf baseline stays f32-gather."""
    if not cfg.cast_once:
        return params
    return jax.tree_util.tree_map(
        lambda a: a.astype(cfg.dtype) if a.dtype == jnp.float32 else a,
        params)

def unembed_matrix(cfg, params):
    """[D, V] output projection (tied or untied)."""
    if cfg.tie_embeddings:
        return params["embed"].astype(cfg.dtype).T
    return params["unembed"].astype(cfg.dtype)


def forward(cfg, params, tokens, vis_embeds=None, return_hidden=False):
    """Training forward: tokens [B,S] -> logits [B,S(+vis),V], aux_loss.
    ``return_hidden`` skips the unembedding and returns the final-normed
    hidden states — the chunked-loss path never materializes [B,S,V]."""
    params = cast_params(cfg, params)
    x = _embed(cfg, params, tokens, vis_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    if cfg.family == "ssm":
        def f(p_l, x):
            return ssm_layer(cfg, p_l, x), None, None
        x, aux, _ = _scan_layers(cfg, f, x, params["layers"])
    elif cfg.family == "hybrid":
        def g(p_g, x):
            def f(p_l, x):
                return ssm_layer(cfg, p_l, x), None, None
            x, _, _ = _scan_layers(cfg, f, x, p_g)
            x, _, _ = dense_layer(cfg, params["shared"], x, positions)
            return x, None, None
        x, aux, _ = _scan_layers(cfg, g, x, params["groups"])
        if "tail" in params:
            def f(p_l, x):
                return ssm_layer(cfg, p_l, x), None, None
            x, _, _ = _scan_layers(cfg, f, x, params["tail"])
    else:
        def f(p_l, x):
            x, kv, aux = dense_layer(cfg, p_l, x, positions)
            return x, None, aux
        x, aux, _ = _scan_layers(cfg, f, x, params["layers"])

    if return_hidden:
        return _apply_norm(cfg, params["final_norm"], x), aux
    return _logits(cfg, params, x), aux


# ---- caches ---------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=None) -> dict:
    """Decode caches, stacked on the layer axis for scan-decode."""
    dtype = dtype or cfg.dtype
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim if cfg.ssm_state else 0
    conv_dim = d_inner + 2 * cfg.ssm_state

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, max_len, K, hd), dtype),
            "v": jnp.zeros((n, batch, max_len, K, hd), dtype),
        }

    def ssm(n):
        return {
            "conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, conv_dim), dtype),
            "ssm": jnp.zeros((n, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                             jnp.float32),
        }

    if cfg.family == "ssm":
        return ssm(L)
    if cfg.family == "hybrid":
        per = cfg.hybrid_attn_every
        G = L // per
        c = {"groups": jax.tree_util.tree_map(
                lambda a: a.reshape((G, per) + a.shape[1:]), ssm(G * per)),
             "shared": kv(G)}
        rem = L - G * per
        if rem:
            c["tail"] = ssm(rem)
        return c
    return kv(L)


def cache_abstract(cfg, batch: int, max_len: int, dtype=None):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype)),
    )


def prefill(cfg, params, tokens, max_len: int, vis_embeds=None):
    """Full-sequence forward that also fills the decode cache.

    Returns (logits, cache). Cache KV buffers are sized ``max_len``.
    """
    params = cast_params(cfg, params)
    x = _embed(cfg, params, tokens, vis_embeds)
    Bsz, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    pad = max_len - S

    def pad_kv(k):
        return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))

    if cfg.family == "ssm":
        def f(p_l, x):
            x, st = ssm_layer_prefill(cfg, p_l, x)
            return x, st, None
        x, _, states = _scan_layers(cfg, f, x, params["layers"], collect=True)
        cache = {"conv": states[0], "ssm": states[1]}
    elif cfg.family == "hybrid":
        def g(p_g, x):
            def f(p_l, x):
                x, st = ssm_layer_prefill(cfg, p_l, x)
                return x, st, None
            x, _, states = _scan_layers(cfg, f, x, p_g, collect=True)
            x, kv, _ = dense_layer(cfg, params["shared"], x, positions)
            return x, (states, (pad_kv(kv[0]), pad_kv(kv[1]))), None
        x, _, ys = _scan_layers(cfg, g, x, params["groups"], collect=True)
        states, kvs = ys
        cache = {
            "groups": {"conv": states[0], "ssm": states[1]},
            "shared": {"k": kvs[0], "v": kvs[1]},
        }
        if "tail" in params:
            def f(p_l, x):
                x, st = ssm_layer_prefill(cfg, p_l, x)
                return x, st, None
            x, _, states = _scan_layers(cfg, f, x, params["tail"],
                                        collect=True)
            cache["tail"] = {"conv": states[0], "ssm": states[1]}
    else:
        def f(p_l, x):
            x, kv, aux = dense_layer(cfg, p_l, x, positions)
            return x, (pad_kv(kv[0]), pad_kv(kv[1])), aux
        x, _, kvs = _scan_layers(cfg, f, x, params["layers"], collect=True)
        cache = {"k": kvs[0], "v": kvs[1]}

    return _logits(cfg, params, x[:, -1:]), cache


def decode_step(cfg, params, cache, tokens, pos):
    """One decode step. tokens [B,1]; pos scalar int32 (absolute position,
    including any vis prefix). Returns (logits [B,1,V], new cache)."""
    params = cast_params(cfg, params)
    x = params["embed"].astype(cfg.dtype)[tokens]

    if cfg.family == "ssm":
        def f(x, inp):
            p_l, c, s = inp
            x, c2, s2 = ssm_layer_decode(cfg, p_l, x, c, s)
            return x, (c2, s2)
        x, (conv, ssm) = jax.lax.scan(
            f, x, (params["layers"], cache["conv"], cache["ssm"]),
            unroll=cfg.scan_unroll)
        new_cache = {"conv": conv, "ssm": ssm}
    elif cfg.family == "hybrid":
        def g(x, inp):
            p_g, cg, kvg = inp
            def f(x, inp2):
                p_l, c, s = inp2
                x, c2, s2 = ssm_layer_decode(cfg, p_l, x, c, s)
                return x, (c2, s2)
            x, (conv, ssm) = jax.lax.scan(
                f, x, (p_g, cg["conv"], cg["ssm"]),
                unroll=cfg.scan_unroll)
            x, (k2, v2) = _attn_block_decode(
                cfg, params["shared"], x, kvg["k"], kvg["v"], pos)
            x, _ = _ffn_block(
                dataclasses.replace(cfg, family="dense"), params["shared"], x)
            return x, ({"conv": conv, "ssm": ssm}, {"k": k2, "v": v2})
        x, (groups, shared) = jax.lax.scan(
            g, x, (params["groups"], cache["groups"], cache["shared"]),
            unroll=cfg.scan_unroll)
        new_cache = {"groups": groups, "shared": shared}
        if "tail" in params:
            def f(x, inp2):
                p_l, c, s = inp2
                x, c2, s2 = ssm_layer_decode(cfg, p_l, x, c, s)
                return x, (c2, s2)
            x, (conv, ssm) = jax.lax.scan(
                f, x, (params["tail"], cache["tail"]["conv"],
                       cache["tail"]["ssm"]), unroll=cfg.scan_unroll)
            new_cache["tail"] = {"conv": conv, "ssm": ssm}
    else:
        def f(x, inp):
            p_l, k, v = inp
            x, (k2, v2) = _attn_block_decode(cfg, p_l, x, k, v, pos)
            x, _ = _ffn_block(cfg, p_l, x)
            return x, (k2, v2)
        x, (k, v) = jax.lax.scan(
            f, x, (params["layers"], cache["k"], cache["v"]),
            unroll=cfg.scan_unroll)
        new_cache = {"k": k, "v": v}

    return _logits(cfg, params, x), new_cache
