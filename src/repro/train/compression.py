"""Gradient compression for cross-pod (DCN) traffic.

Two composable schemes:
  * top-k sparsification with error feedback (EF-SGD): only the largest
    |g| fraction crosses the slow axis; the residual accumulates locally
    and is re-injected next step (provably convergent);
  * int8 quantization: per-tensor max-abs scaling (8× over f32 on the wire,
    4× over bf16).

These are grad_transform hooks for ``make_train_step``; the simulated
bytes-on-wire reduction feeds the collective roofline term (§Perf) and the
paper-allocator's DCN flow weights.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ int8
def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def int8_roundtrip(tree):
    """Simulates an int8-compressed collective payload: quantize/dequantize
    every leaf. On real DCN hardware the int8 buffer is what crosses pods."""
    def f(x):
        q, s = quantize_int8(x)
        return dequantize_int8(q, s).astype(x.dtype)
    return jax.tree_util.tree_map(f, tree)


# ---------------------------------------------------------------- top-k EF
class EFState(NamedTuple):
    error: Any  # residual tree


def ef_init(params) -> EFState:
    return EFState(error=jax.tree_util.tree_map(jnp.zeros_like, params))


def topk_ef_transform(grads, state: EFState, fraction: float = 0.01):
    """Keep the top-``fraction`` of |g + err| per leaf; the rest becomes the
    next step's error. Returns (sparse_grads, new_state)."""
    def f(g, e):
        ge = g + e
        flat = jnp.abs(ge.reshape(-1))
        k = max(int(flat.shape[0] * fraction), 1)
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = (jnp.abs(ge) >= thresh).astype(ge.dtype)
        kept = ge * mask
        return kept, ge - kept

    flat = jax.tree_util.tree_map(f, grads, state.error)
    kept = jax.tree_util.tree_map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return kept, EFState(error=err)


def compressed_bytes_ratio(fraction: float, index_bits: int = 32,
                           value_bits: int = 16) -> float:
    """Wire-bytes ratio of top-k EF vs dense bf16 (for the roofline model):
    each kept value ships (index, value)."""
    dense_bits = 16.0
    sparse_bits = fraction * (index_bits + value_bits)
    return sparse_bits / dense_bits


def make_dcn_compressor(fraction: float = 0.01, int8: bool = True):
    """grad_transform factory for make_train_step: top-k EF (+ int8 payload
    simulation). State is threaded via closure-captured mutation-free usage:
    returns (init_state, transform(grads, state) -> (grads, state))."""
    def transform(grads, state: EFState):
        kept, state = topk_ef_transform(grads, state, fraction)
        if int8:
            kept = int8_roundtrip(kept)
        return kept, state
    return ef_init, transform
