"""Fault-tolerant training driver.

Production behaviours, exercised deterministically on CPU:
  * checkpoint/restart — periodic async checkpoints; on step failure the
    driver restores the latest checkpoint and replays (the data pipeline is
    stateless-by-step, so the token stream resumes exactly);
  * straggler mitigation — per-step deadline; a straggling step is
    re-executed (deterministic backup replay — the analogue of backup
    workers at pod scale), and repeated stragglers raise the deadline;
  * elastic re-scale — a resize event rebuilds the mesh over the new chip
    count and re-shards params/optimizer through the checkpointer's
    device_put path;
  * failure injection — ``failure_at`` (steps that raise) and
    ``straggle_at`` (steps that sleep past the deadline) let tests verify
    the recovery paths end-to-end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.data.pipeline import SyntheticLM
from repro.models.registry import ModelApi
from repro.train.checkpoint import Checkpointer
from repro.train.optim import AdamW
from repro.train.step import make_train_step


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class DriverConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    deadline_s: float = 1e9          # straggler threshold
    max_retries: int = 3
    keep: int = 3


class TrainDriver:
    def __init__(self, api: ModelApi, opt: AdamW, pipe: SyntheticLM,
                 dcfg: DriverConfig,
                 failure_at: set[int] | None = None,
                 straggle_at: dict[int, float] | None = None,
                 extra_batch: Callable[[int], dict] | None = None):
        self.api = api
        self.opt = opt
        self.pipe = pipe
        self.dcfg = dcfg
        self.ckpt = Checkpointer(dcfg.ckpt_dir, keep=dcfg.keep)
        self.step_fn = jax.jit(make_train_step(api, opt))
        self.failure_at = failure_at or set()
        self.straggle_at = straggle_at or {}
        self.extra_batch = extra_batch
        self.events: list[tuple[int, str]] = []
        self.metrics: list[dict] = []

    # ---------------------------------------------------------------- run
    def run(self, params=None, opt_state=None) -> tuple[Any, Any, int]:
        if params is None:
            params = self.api.init(jax.random.PRNGKey(0))
        if opt_state is None:
            opt_state = self.opt.init(params)
        start = 0
        if self.ckpt.latest_step() is not None:
            (params, opt_state), start = self._restore(params, opt_state)
            self.events.append((start, "restored"))

        step = start
        retries = 0
        deadline = self.dcfg.deadline_s
        while step < self.dcfg.steps:
            batch = self._batch(step)
            t0 = time.time()
            try:
                if step in self.failure_at and retries == 0:
                    self.failure_at.discard(step)
                    raise InjectedFailure(f"injected failure at step {step}")
                if step in self.straggle_at:
                    time.sleep(self.straggle_at.pop(step))
                params2, opt_state2, m = self.step_fn(params, opt_state,
                                                      batch)
                jax.block_until_ready(m["loss"])
            except InjectedFailure as e:
                self.events.append((step, f"failure: {e}"))
                retries += 1
                if retries > self.dcfg.max_retries:
                    raise
                (params, opt_state), step = self._restore(params, opt_state)
                self.events.append((step, "restart-from-ckpt"))
                continue
            wall = time.time() - t0
            if wall > deadline:
                # straggler: deterministic backup replay, then widen the
                # deadline so a persistently slow host doesn't livelock
                self.events.append((step, f"straggler {wall:.3f}s"))
                deadline = max(deadline, wall * 1.5)
                params2, opt_state2, m = self.step_fn(params, opt_state,
                                                      batch)
            params, opt_state = params2, opt_state2
            retries = 0
            self.metrics.append(
                {"step": step, "loss": float(m["loss"]), "wall_s": wall})
            step += 1
            if step % self.dcfg.ckpt_every == 0:
                self.ckpt.save_async(step, {"params": params,
                                            "opt": opt_state})
        self.ckpt.wait()
        return params, opt_state, step

    # ------------------------------------------------------------ helpers
    def _batch(self, step: int) -> dict:
        b = {k: jax.numpy.asarray(v) for k, v in self.pipe.batch(step).items()}
        if self.extra_batch is not None:
            b.update(self.extra_batch(step))
        return b

    def _restore(self, params, opt_state):
        state, step = self.ckpt.restore(
            {"params": params, "opt": opt_state})
        return (state["params"], state["opt"]), step

    # ------------------------------------------------------------ elastic
    def reshard_to(self, params, opt_state, shardings_params,
                   shardings_opt) -> tuple[Any, Any]:
        """Elastic re-scale: round-trip through host memory onto a NEW mesh
        (chip count may differ — e.g. a pod dropped out)."""
        self.ckpt.save(0x7FFFFFFF, {"params": params, "opt": opt_state})
        state, _ = self.ckpt.restore(
            {"params": params, "opt": opt_state}, step=0x7FFFFFFF,
            shardings={"params": shardings_params, "opt": shardings_opt})
        return state["params"], state["opt"]
