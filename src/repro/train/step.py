"""Loss and train-step factories (arch-agnostic via the ModelApi)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi
from repro.train import optim as O


def softmax_xent(logits, labels, z_loss: float = 0.0):
    """Mean cross-entropy in f32. labels: int32, -1 = masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_softmax_xent(hidden, w_unembed, labels, chunk: int = 512,
                         z_loss: float = 0.0, unroll: bool = False):
    """Cross-entropy without materializing [B,S,V]: scan over sequence
    chunks; each chunk's logits are rematerialized in backward
    (jax.checkpoint), so peak memory is [B,chunk,V]."""
    Bsz, S, D = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    h = hidden.reshape(Bsz, nc, chunk, D).swapaxes(0, 1)
    y = labels.reshape(Bsz, nc, chunk).swapaxes(0, 1)

    from repro.sharding.policy import shard_as

    @jax.checkpoint
    def chunk_loss(h_c, y_c):
        logits = (h_c @ w_unembed).astype(jnp.float32)
        logits = shard_as(logits, "batch", "act_seq", "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        nll = lse - ll
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        mask = (y_c >= 0).astype(jnp.float32)
        return jnp.sum(nll * mask), jnp.sum(mask)

    def body(carry, inp):
        tot, cnt = carry
        s, c = chunk_loss(*inp)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h, y), unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(api: ModelApi, aux_weight: float = 0.01,
                 z_loss: float = 0.0, loss_unroll: bool = False):
    cfg = api.cfg

    def loss_fn(params, batch):
        hidden, aux = api.forward_hidden(params, batch)
        if cfg.family == "vlm":
            # vision-prefix positions carry no token loss
            hidden = hidden[:, cfg.n_vis_tokens:]
        w = api.unembed(params)
        loss = chunked_softmax_xent(hidden, w, batch["labels"],
                                    z_loss=z_loss, unroll=loss_unroll)
        metrics = {"xent": loss}
        if aux is not None:
            loss = loss + aux_weight * aux
            metrics["moe_aux"] = aux
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(api: ModelApi, optimizer: O.AdamW,
                    microbatches: int = 1, grad_transform=None,
                    aux_weight: float = 0.01, loss_unroll: bool = False,
                    constrain_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``microbatches`` > 1 accumulates gradients over equal splits
    of the batch (sequential scan — memory-bounded pipelines).
    ``grad_transform(grads) -> grads`` hooks in compression (top-k EF, int8).
    ``constrain_grads`` pins gradient shardings to the parameter shardings
    (steers XLA toward reduce-scatter instead of all-reduce+slice on the
    FSDP axis)."""
    loss_fn = make_loss_fn(api, aux_weight, loss_unroll=loss_unroll)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    from repro.sharding.policy import shard_as

    def _is_axes(x):
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        if constrain_grads:
            grads = jax.tree_util.tree_map(
                lambda ax, g: shard_as(g, *ax), api.param_axes(), grads,
                is_leaf=_is_axes)
        return grads, metrics

    def accumulate(params, batch):
        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mb = jax.tree_util.tree_map(split, batch)

        def body(carry, b):
            acc, _ = carry
            grads, metrics = single(params, b)
            metrics = {k: metrics[k] for k in ("xent", "loss")}
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, metrics), None

        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        (grads, metrics), _ = jax.lax.scan(
            body, (zeros, _zero_metrics()), mb)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            grads, metrics = accumulate(params, batch)
        else:
            grads, metrics = single(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        updates, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        params = O.apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def _zero_metrics():
    z = jnp.zeros((), jnp.float32)
    return {"xent": z, "loss": z}
