"""Optimizers & schedules — self-contained (no optax in this container).

AdamW with decoupled weight decay, global-norm clipping, and warmup+cosine
schedule. States mirror the param tree (same shapes ⇒ same shardings), so
FSDP sharding of the optimizer comes for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant(lr_value: float) -> Callable:
    return lambda step: jnp.asarray(lr_value, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params) -> AdamState:
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return AdamState(step=jnp.zeros((), jnp.int32), m=z,
                         v=jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        else:
            gn = global_norm(grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state.v, grads)
        t = step.astype(jnp.float32)
        mc = 1 - b1 ** t
        vc = 1 - b2 ** t
        lr = self._lr(step)

        def upd(m_, v_, p):
            u = (m_ / mc) / (jnp.sqrt(v_ / vc) + self.eps)
            return -lr * (u + self.weight_decay * p)

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, AdamState(step=step, m=m, v=v), gn


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
