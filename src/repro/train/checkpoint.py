"""Checkpointing: atomic, async, integrity-checked, reshard-on-restore.

Layout (one directory per step):
    <dir>/ckpt_<step>/arrays.npz     flattened param/opt tree
    <dir>/ckpt_<step>/manifest.json  step, tree structure, shapes, sha256s

Guarantees:
  * atomicity — written to ``.tmp`` then os.rename (a crash never leaves a
    half-readable checkpoint);
  * async — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes on a background thread, so the train loop is not blocked;
  * integrity — per-array sha256 recorded and verified on restore;
  * elasticity — restore takes target shardings: arrays are ``device_put``
    onto ANY mesh (different chip count than the writer — the elastic
    re-scale path);
  * retention — keep the newest ``keep`` checkpoints.

At 1000+ node scale each host writes only its owned shards; this container
is single-host so arrays are written whole. The manifest format already
records per-array shapes so a sharded writer is a drop-in change.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        flat = _flatten(state)  # host copy (synchronous snapshot)
        if blocking:
            self._write(step, flat)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._thread.start()

    def save_async(self, step: int, state: Any) -> None:
        self.save(step, state, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        final = self.dir / f"ckpt_{step:08d}"
        tmp = self.dir / f".ckpt_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "sha256": {k: _sha(v) for k, v in flat.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("ckpt_*"))
        for old in ckpts[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        ckpts = sorted(self.dir.glob("ckpt_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None, verify: bool = True) -> tuple[Any, int]:
        """Restore into the structure of ``like``. ``shardings`` (same
        structure or None) places arrays onto the CURRENT mesh — elastic
        restores onto a different chip count just pass new shardings."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"ckpt_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        if verify:
            for k, a in arrays.items():
                got = _sha(a)
                want = manifest["sha256"][k]
                if got != want:
                    raise IOError(f"checkpoint corruption at {k}: "
                                  f"{got} != {want}")
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, leaf), sh in zip(paths, sh_leaves):
            key = SEP.join(_path_str(p) for p in path)
            a = arrays[key]
            if hasattr(leaf, "dtype"):
                a = a.astype(leaf.dtype)
            leaves.append(jax.device_put(a, sh) if sh is not None
                          else jax.numpy.asarray(a))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
