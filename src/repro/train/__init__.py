from repro.train.optim import AdamW, AdamState, apply_updates, warmup_cosine  # noqa: F401
from repro.train.step import make_loss_fn, make_train_step, softmax_xent  # noqa: F401
