"""Jitted wrapper for flash attention: layout adaptation + backend select.

Model code uses [B,S,H,hd] activations; the kernel wants [B,H,S,hd].
On CPU runs interpret mode (validated vs ref); on TPU runs compiled.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] -> [B,S,H,hd]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


def flash_attention_reference(q, k, v, causal: bool = True):
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    return attention_ref(qt, kt, vt, causal).transpose(0, 2, 1, 3)
