"""Pure-jnp oracle for flash attention (GQA, optional causal)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, causal: bool = True):
    """q: [B,H,S,hd]; k,v: [B,K,T,hd]; H = K·G. -> [B,H,S,hd] (f32 softmax)."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    q = q.reshape(B, K, G, S, hd)
    scores = jnp.einsum("bkgsd,bktd->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        T = k.shape[2]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)
