"""Pallas TPU flash attention (prefill/train path, GQA-aware).

Canonical online-softmax tiling for the MXU:
  grid = (B·H, S/BQ, T/BK) with the KV dimension innermost ("arbitrary"
  semantics). Per (b,h,qblk): f32 scratch accumulators (acc [BQ,hd],
  m/l [BQ,1]) persist across KV steps; initialized at kv==0 and written out
  (acc/l) at the last KV step. Causal programs where the whole KV block is
  masked are skipped via ``pl.when`` wrapping the compute.

Block sizes default to MXU-aligned 128×128 tiles; VMEM per program =
BQ·hd + 2·BK·hd + BQ·BK f32 ≈ 0.2 MB at defaults — far under the ~16 MB
VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across JAX releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip programs with no unmasked key (kv block fully after q blk)
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # [BQ, hd]
        k = k_ref[0].astype(jnp.float32)                   # [BK, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [BQ, BK]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]                                # [BQ, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # [BQ, BK]
        corr = jnp.exp(m_prev - m_new)                     # [BQ, 1]
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: [B,H,S,hd]; k,v: [B,K,T,hd] with H = K·G. Returns [B,H,S,hd].

    KV heads are indexed via the grid (no repeat materialization).
    """
    B, H, S, hd = q.shape
    _, K, T, _ = k.shape
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    grid = (B * H, S // bq, T // bk)
    scale = 1.0 / math.sqrt(hd)

    qs = pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0))
    ks = pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0))
    out = pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0))

    o = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, n_kv=T // bk),
        grid=grid,
        in_specs=[qs, ks, ks],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q.reshape(B * H, S, hd), k.reshape(B * K, T, hd),
      v.reshape(B * K, T, hd))
    return o.reshape(B, H, S, hd)
