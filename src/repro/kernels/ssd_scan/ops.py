"""Jitted SSD forward assembled from the Pallas intra-chunk kernel plus the
XLA inter-chunk recurrence (linear scan over chunk states)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas
from repro.kernels.ssd_scan.ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, chunk: int = 128, interpret: bool | None = None):
    """Full SSD: x [B,S,H,P], dt [B,S,H], A [H], B/C [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    # rearrange to kernel layout
    xk = x.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        Bsz * H, nc, chunk, P)
    dtk = dt.astype(jnp.float32).transpose(0, 2, 1).reshape(
        Bsz * H, nc, chunk, 1)
    Bk = B.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Ck = C.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Ak = jnp.repeat(A.astype(jnp.float32)[None, :], Bsz, 0).reshape(
        Bsz * H, 1)

    y_intra, states, cum = ssd_chunk_pallas(xk, dtk, Bk, Ck, Ak,
                                            interpret=interpret)
    cum = cum[..., 0]                                   # [BH, nc, Q]
    chunk_decay = jnp.exp(cum[:, :, -1])                # [BH, nc]

    # inter-chunk recurrence (linear):
    def step(h, inp):
        st, dec = inp
        h_new = h * dec[:, None, None] + st
        return h_new, h

    h0 = jnp.zeros((Bsz * H, P, N), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                      # [BH, nc, P, N]

    # combine: y = y_intra + exp(cum)·(C · h_prev)
    Ck_bh = jnp.repeat(Ck[:, None], H, 1).reshape(Bsz * H, nc, chunk, N)
    y_inter = jnp.einsum("hcqn,hcpn->hcqp", Ck_bh, h_prev) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, H, S, P).transpose(0, 2, 1, 3)
    final = h_last.reshape(Bsz, H, P, N)
    return y, final


def ssd_reference(x, dt, A, B, C):
    return ssd_ref(x, dt, A, B, C)
