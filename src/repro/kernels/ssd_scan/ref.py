"""Pure-jnp oracle for the Mamba2/SSD core: naive sequential recurrence.

    h_t = exp(dt_t · A) ⊙ h_{t-1} + dt_t · (B_t ⊗ x_t)
    y_t = C_t · h_t

Shapes: x [B,S,H,P], dt [B,S,H] (post-softplus), A [H] (negative),
B/C [B,S,N] (single group). Returns y [B,S,H,P] and final state
[B,H,P,N]. f32 throughout — this is the ground truth for the chunked
Pallas kernel and for ``blocks.mamba2_forward``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t * A[None, :])                  # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t)
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", C_t, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          B.swapaxes(0, 1), C.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h
