"""Pallas TPU kernel for the SSD intra-chunk compute (Mamba2 hot loop).

Decomposition (DESIGN.md hardware adaptation): the quadratic *intra-chunk*
work — an attention-like [Q,Q] masked-decay matmul per (batch·head, chunk) —
runs on the MXU inside this kernel; the *inter-chunk* state recurrence is a
cheap linear scan left to XLA in ``ops.py``. Per-program VMEM: x [Q,P],
B/C [Q,N], the [Q,Q] decay/score tile and the [P,N] chunk state —
Q=128, P=64, N=128 ⇒ ~0.2 MB, MXU-aligned.

Outputs per (bh, chunk): y_intra [Q,P], chunk state contribution [P,N],
and the cumulative log-decay cum [Q] (the combine step needs exp(cum)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                      y_ref, st_ref, cum_ref, *, q: int):
    x = x_ref[0, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)  # [Q]
    Bm = b_ref[0, 0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)         # [Q, N]
    A = a_ref[0, 0]                              # scalar (this head)

    dA = dt * A                                  # [Q]
    cum = jnp.cumsum(dA)                         # [Q]
    seg = cum[:, None] - cum[None, :]            # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    Lmat = jnp.where(jj <= ii, jnp.exp(seg), 0.0)

    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    M = G * Lmat * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, P]

    decay_end = jnp.exp(cum[-1] - cum)           # [Q]
    wB = Bm * (dt * decay_end)[:, None]          # [Q, N]
    st = jax.lax.dot_general(x, wB, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, N]

    y_ref[0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0] = st.astype(st_ref.dtype)
    cum_ref[0, 0, :, 0] = cum.astype(cum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(x, dt, B, C, A, interpret: bool = False):
    """x: [BH, nc, Q, P]; dt: [BH, nc, Q, 1]; B/C: [BHg, nc, Q, N] with
    BHg = batch (heads share B/C); A: [BH, 1]. Heads of the same batch map
    to the same B/C block via the grid index.

    Returns (y_intra [BH,nc,Q,P], states [BH,nc,P,N], cum [BH,nc,Q,1]).
    """
    BH, nc, Q, P = x.shape
    Bsz = B.shape[0]
    H = BH // Bsz
    N = B.shape[-1]

    grid = (BH, nc)
    xs = pl.BlockSpec((1, 1, Q, P), lambda h, c: (h, c, 0, 0))
    ds = pl.BlockSpec((1, 1, Q, 1), lambda h, c: (h, c, 0, 0))
    bs = pl.BlockSpec((1, 1, Q, N), lambda h, c: (h // H, c, 0, 0))
    as_ = pl.BlockSpec((1, 1), lambda h, c: (h, 0))
    ys = pl.BlockSpec((1, 1, Q, P), lambda h, c: (h, c, 0, 0))
    ss = pl.BlockSpec((1, 1, P, N), lambda h, c: (h, c, 0, 0))
    cs = pl.BlockSpec((1, 1, Q, 1), lambda h, c: (h, c, 0, 0))

    return pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, q=Q),
        grid=grid,
        in_specs=[xs, ds, bs, bs, as_],
        out_specs=[ys, ss, cs],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, P, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, Q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, B, C, A)
