"""Jitted wrapper for the waterfill kernel: padding, backend selection.

On TPU the Pallas kernel runs compiled; on CPU (this container) it runs in
``interpret=True`` mode, which executes the kernel body per-program in
Python — bit-identical control flow, validated against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.waterfill.kernel import waterfill_pallas
from repro.kernels.waterfill.ref import waterfill_ref


def _pad_to(x, n, axis, value=0.0):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def waterfill(weights, backlog, rho, mask, capacity, kind, dt: float = 1.0,
              block_links: int = 8, interpret: bool | None = None):
    """Batched per-link allocator solve. Shapes: [L, F] + [L]; returns [L, F].

    Pads F to a 128-lane multiple and L to the link-block multiple, then
    dispatches to the Pallas kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L, F = weights.shape
    Fp = int(np.ceil(F / 128) * 128)
    Lp = int(np.ceil(L / block_links) * block_links)
    args = [
        _pad_to(_pad_to(jnp.asarray(a, jnp.float32), Fp, 1), Lp, 0)
        for a in (weights, backlog, rho, mask)
    ]
    cap = _pad_to(jnp.asarray(capacity, jnp.float32), Lp, 0)
    knd = _pad_to(jnp.asarray(kind, jnp.int32), Lp, 0)
    out = waterfill_pallas(*args, cap, knd, dt=dt, block_links=block_links,
                           interpret=interpret)
    return out[:L, :F]


def waterfill_reference(weights, backlog, rho, mask, capacity, kind,
                        dt: float = 1.0):
    return waterfill_ref(weights, backlog, rho, mask, capacity, kind, dt)
