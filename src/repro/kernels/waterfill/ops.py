"""Jitted wrapper for the waterfill kernel: padding, backend selection.

On TPU the Pallas kernel runs compiled; on CPU (this container) it runs in
``interpret=True`` mode, which executes the kernel body per-program in
Python — bit-identical control flow, validated against ``ref.py``.

Padding happens *inside* one jitted function whose pad targets are static
arguments derived from the input shapes, so repeat calls at the same shape
hit the jit cache instead of re-dispatching un-jitted ``jnp.pad`` ops for
both axes on every call.

Two entry points share the kernel:

* :func:`waterfill` — dense per-link [L, F] inputs (the oracle cross-check
  surface: every link may carry its own w/backlog/ρ);
* :func:`waterfill_flows` — per-flow [F] vectors shared by all links (the
  allocator hot path: only the on-link mask is per-link, so the dense
  broadcasts are never materialized).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.waterfill.kernel import waterfill_pallas
from repro.kernels.waterfill.ref import waterfill_ref


def _pad_to(x, n, axis, value=0.0):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("dt", "block_links", "block_flows", "interpret",
                     "Fp", "Lp"))
def _waterfill_padded(weights, backlog, rho, mask, capacity, kind, *,
                      dt, block_links, block_flows, interpret, Fp, Lp):
    L, F = mask.shape
    w, b, r = (jnp.asarray(a, jnp.float32) for a in (weights, backlog, rho))
    if w.ndim == 2:  # dense per-link inputs
        w, b, r = (_pad_to(_pad_to(a, Fp, 1), Lp, 0) for a in (w, b, r))
    else:            # shared per-flow vectors
        w, b, r = (_pad_to(a, Fp, 0) for a in (w, b, r))
    m = _pad_to(_pad_to(jnp.asarray(mask, jnp.float32), Fp, 1), Lp, 0)
    cap = _pad_to(jnp.asarray(capacity, jnp.float32), Lp, 0)
    knd = _pad_to(jnp.asarray(kind, jnp.int32), Lp, 0)
    out = waterfill_pallas(w, b, r, m, cap, knd, dt=dt,
                           block_links=block_links, block_flows=block_flows,
                           interpret=interpret)
    return out[:L, :F]


def _dispatch(weights, backlog, rho, mask, capacity, kind, dt, block_links,
              block_flows, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_flows is not None:
        assert block_flows % 128 == 0, block_flows
    L, F = mask.shape
    bf = 128 if block_flows is None else block_flows
    Fp = -(-F // bf) * bf
    Lp = -(-L // block_links) * block_links
    return _waterfill_padded(
        weights, backlog, rho, mask, capacity, kind, dt=dt,
        block_links=block_links, block_flows=block_flows,
        interpret=interpret, Fp=Fp, Lp=Lp)


def waterfill(weights, backlog, rho, mask, capacity, kind, dt: float = 1.0,
              block_links: int = 8, block_flows: int | None = None,
              interpret: bool | None = None):
    """Batched per-link allocator solve, dense per-link inputs.

    Shapes: weights/backlog/rho/mask [L, F] + capacity/kind [L];
    returns [L, F]. Padding to lane/block multiples is jit-cached.
    """
    return _dispatch(weights, backlog, rho, mask, capacity, kind, dt,
                     block_links, block_flows, interpret)


def waterfill_flows(weights, backlog, rho, mask, capacity, kind,
                    dt: float = 1.0, block_links: int = 8,
                    block_flows: int | None = None,
                    interpret: bool | None = None):
    """Batched per-link solve with *shared* per-flow inputs.

    weights/backlog/rho: [F] (the same flow state is visible to every
    link); mask: [L, F]; capacity/kind: [L]. Returns [L, F]. Equivalent to
    :func:`waterfill` on ``jnp.broadcast_to(v, (L, F))`` inputs without
    ever materializing the broadcasts.
    """
    assert weights.ndim == 1, weights.shape
    return _dispatch(weights, backlog, rho, mask, capacity, kind, dt,
                     block_links, block_flows, interpret)


def waterfill_reference(weights, backlog, rho, mask, capacity, kind,
                        dt: float = 1.0):
    return waterfill_ref(weights, backlog, rho, mask, capacity, kind, dt)
