"""Pure-jnp oracle for the batched per-link allocator solves.

Link semantics (paper Alg. 1):
  kind 0 (uplink, eq. 3):  x_f = C · w_f / Σ w   (proportional-to-demand)
  kind 1 (downlink, eq. 4): water-filling x_f = max(0, (θ ρ_f − L_f)/dt)
                            with θ s.t. Σ x_f = C  (equal drain times)

The oracle reuses the exact sort-based solvers from ``repro.core.allocator``
vmapped over the link batch — the Pallas kernel must match it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.allocator import solve_downlink, solve_uplink


def waterfill_ref(weights, backlog, rho, mask, capacity, kind, dt: float):
    """weights/backlog/rho/mask: [L, F]; capacity/kind: [L]. -> rates [L, F]."""

    def one(w, L_, r, m, c, k):
        up = solve_uplink(w, m, c)
        down = solve_downlink(L_, r, m, c, dt)
        return jnp.where(k == 1, down, up)

    return jax.vmap(one)(weights, backlog, rho, mask, capacity, kind)
