"""Pallas TPU kernel: batched per-link bandwidth solves (paper Alg. 1 hot loop).

At datacenter scale the allocator solves one small optimization per
bottlenecked link every Δt — thousands of links × thousands of flows. That
inner loop is this kernel. TPU adaptation (DESIGN.md): the exact sort-based
water-filling used on CPU is replaced with **fixed-iteration bisection on
θ** — sorts are lane-hostile on the VPU, while bisection is 40 rounds of
pure vector ops on a [links_block × flows] tile resident in VMEM.

Tiling: grid over link blocks; each program holds (BL, F) tiles of
weights/backlog/rho/mask plus (BL, 1) capacity/kind in VMEM. F is padded to
a lane multiple (128) by ``ops.py``; padded flows carry mask 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BISECT = 48
_EPS = 1e-9


def _waterfill_block(w_ref, L_ref, r_ref, m_ref, cap_ref, kind_ref, out_ref,
                     *, dt: float):
    w = w_ref[...].astype(jnp.float32)
    L = L_ref[...].astype(jnp.float32)
    rho = jnp.maximum(r_ref[...].astype(jnp.float32), _EPS)
    m = m_ref[...].astype(jnp.float32)
    cap = cap_ref[...].astype(jnp.float32)          # [BL, 1]
    kind = kind_ref[...]                            # [BL, 1] int32

    # ---- eq. (3): proportional-to-demand (uplinks) --------------------
    wm = jnp.maximum(w, 0.0) * m
    tot = jnp.sum(wm, axis=1, keepdims=True)
    n = jnp.sum(m, axis=1, keepdims=True)
    wm = jnp.where(tot > _EPS, wm, m)               # zero demand: equal split
    tot = jnp.where(tot > _EPS, tot, jnp.maximum(n, 1.0))
    x_up = cap * wm / tot

    # ---- eq. (4): drain-time equalization via bisection (downlinks) ---
    theta_act = jnp.where(m > 0, L / rho, 0.0)
    lo = jnp.zeros_like(cap)
    sum_rho = jnp.sum(rho * m, axis=1, keepdims=True)
    hi = (jnp.max(theta_act, axis=1, keepdims=True)
          + cap * dt / jnp.maximum(sum_rho, _EPS) + 1.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        alloc = jnp.sum(jnp.maximum(mid * rho - L, 0.0) * m / dt,
                        axis=1, keepdims=True)
        too_much = alloc > cap
        return jnp.where(too_much, lo, mid), jnp.where(too_much, mid, hi)

    lo, hi = jax.lax.fori_loop(0, N_BISECT, body, (lo, hi))
    theta = 0.5 * (lo + hi)
    x_down = jnp.maximum(theta * rho - L, 0.0) * m / dt
    # exact capacity: renormalize residual bisection error
    s = jnp.sum(x_down, axis=1, keepdims=True)
    x_down = jnp.where(s > _EPS, x_down * (cap / s), x_down)

    out_ref[...] = jnp.where(kind == 1, x_down, x_up).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dt", "block_links", "interpret"))
def waterfill_pallas(weights, backlog, rho, mask, capacity, kind,
                     dt: float = 1.0, block_links: int = 8,
                     interpret: bool = False):
    """weights/backlog/rho/mask: [L, F] (F a multiple of 128 — see ops.py);
    capacity: [L]; kind: [L] int32 (0 uplink / 1 downlink). -> [L, F]."""
    Lnum, F = weights.shape
    assert Lnum % block_links == 0, (Lnum, block_links)
    cap2 = capacity.reshape(Lnum, 1).astype(jnp.float32)
    kind2 = kind.reshape(Lnum, 1).astype(jnp.int32)

    grid = (Lnum // block_links,)
    row = pl.BlockSpec((block_links, F), lambda i: (i, 0))
    col = pl.BlockSpec((block_links, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_waterfill_block, dt=dt),
        grid=grid,
        in_specs=[row, row, row, row, col, col],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((Lnum, F), jnp.float32),
        interpret=interpret,
    )(weights, backlog, rho, mask, cap2, kind2)
