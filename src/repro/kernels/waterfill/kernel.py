"""Pallas TPU kernel: batched per-link bandwidth solves (paper Alg. 1 hot loop).

At datacenter scale the allocator solves one small optimization per
bottlenecked link every Δt — thousands of links × thousands of flows. That
inner loop is this kernel. TPU adaptation (DESIGN.md): the exact sort-based
water-filling used on CPU is replaced with **fixed-iteration bisection on
θ** — sorts are lane-hostile on the VPU, while bisection is 48 rounds of
pure vector ops on a [links_block × flows] tile resident in VMEM.

Tiling: grid over link blocks; each program holds a (BL, F) mask tile plus
(BL, 1) capacity/kind in VMEM. The per-flow inputs (demand w, backlog L^r,
drain ρ) are the *same* for every link, so they ship as (1, F) rows mapped
to every grid step instead of dense [L, F] broadcasts (the allocator path;
``ops.waterfill`` still accepts per-link dense inputs for the oracle
cross-checks). Inside a program the flow axis is walked in ``block_flows``
chunks — F is VMEM-resident either way, but the chunking bounds the vector
working set per op so F = 10³–10⁴ doesn't force one giant lane block
through every reduction. F is padded to a lane/chunk multiple by
``ops.py``; padded flows carry mask 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BISECT = 48
_EPS = 1e-9


def _waterfill_block(w_ref, L_ref, r_ref, m_ref, cap_ref, kind_ref, out_ref,
                     *, dt: float, block_flows: int):
    """One link block. w/L/r refs are (1, F) shared rows or (BL, F) dense;
    broadcasting against the (BL, BF) mask chunks covers both layouts."""
    F = m_ref.shape[1]
    nT = F // block_flows
    cap = cap_ref[...].astype(jnp.float32)          # [BL, 1]
    kind = kind_ref[...]                            # [BL, 1] int32
    zcol = jnp.zeros_like(cap)

    def tile(t):
        sl = pl.ds(t * block_flows, block_flows)
        w = w_ref[:, sl].astype(jnp.float32)
        L = L_ref[:, sl].astype(jnp.float32)
        rho = jnp.maximum(r_ref[:, sl].astype(jnp.float32), _EPS)
        m = m_ref[:, sl].astype(jnp.float32)
        return w, L, rho, m

    # ---- pass 1: per-link reductions over flow chunks -----------------
    def reduce_chunk(t, c):
        s_w, s_m, s_rho, mx = c
        w, L, rho, m = tile(t)
        wm = jnp.maximum(w, 0.0) * m
        th = jnp.where(m > 0, L / rho, 0.0)          # activation points
        return (s_w + jnp.sum(wm, axis=1, keepdims=True),
                s_m + jnp.sum(m, axis=1, keepdims=True),
                s_rho + jnp.sum(rho * m, axis=1, keepdims=True),
                jnp.maximum(mx, jnp.max(th, axis=1, keepdims=True)))

    s_w, s_m, s_rho, mx_th = jax.lax.fori_loop(
        0, nT, reduce_chunk, (zcol, zcol, zcol, zcol))

    # ---- eq. (4): drain-time equalization via bisection (downlinks) ---
    hi0 = mx_th + cap * dt / jnp.maximum(s_rho, _EPS) + 1.0

    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)

        def acc(t, s):
            _, L, rho, m = tile(t)
            return s + jnp.sum(jnp.maximum(mid * rho - L, 0.0) * m,
                               axis=1, keepdims=True)

        alloc = jax.lax.fori_loop(0, nT, acc, zcol) / dt
        too_much = alloc > cap
        return jnp.where(too_much, lo, mid), jnp.where(too_much, mid, hi)

    lo, hi = jax.lax.fori_loop(0, N_BISECT, bisect, (zcol, hi0))
    theta = 0.5 * (lo + hi)

    # downlink mass at θ: renormalize residual bisection error to capacity
    def mass(t, s):
        _, L, rho, m = tile(t)
        return s + jnp.sum(jnp.maximum(theta * rho - L, 0.0) * m,
                           axis=1, keepdims=True)

    s_dn = jax.lax.fori_loop(0, nT, mass, zcol) / dt
    dn_scale = jnp.where(s_dn > _EPS, cap / s_dn, 1.0)

    # ---- eq. (3) scalars: zero demand falls back to equal split -------
    up_fb = s_w <= _EPS
    up_den = jnp.where(up_fb, jnp.maximum(s_m, 1.0), s_w)

    def emit(t, _):
        sl = pl.ds(t * block_flows, block_flows)
        w, L, rho, m = tile(t)
        wm = jnp.where(up_fb, m, jnp.maximum(w, 0.0) * m)
        x_up = cap * wm / up_den
        x_dn = jnp.maximum(theta * rho - L, 0.0) * m / dt * dn_scale
        out_ref[:, sl] = jnp.where(kind == 1, x_dn, x_up).astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, nT, emit, 0)


@functools.partial(
    jax.jit, static_argnames=("dt", "block_links", "block_flows", "interpret"))
def waterfill_pallas(weights, backlog, rho, mask, capacity, kind,
                     dt: float = 1.0, block_links: int = 8,
                     block_flows: int | None = None,
                     interpret: bool = False):
    """mask: [L, F] (F a multiple of 128 and of ``block_flows`` — see
    ops.py); weights/backlog/rho: [F] per-flow vectors (shared across links)
    or dense [L, F]; capacity: [L]; kind: [L] int32 (0 uplink / 1 downlink).
    -> [L, F]."""
    Lnum, F = mask.shape
    assert Lnum % block_links == 0, (Lnum, block_links)
    bf = F if block_flows is None else block_flows
    assert F % bf == 0, (F, bf)
    cap2 = capacity.reshape(Lnum, 1).astype(jnp.float32)
    kind2 = kind.reshape(Lnum, 1).astype(jnp.int32)

    grid = (Lnum // block_links,)
    row = pl.BlockSpec((block_links, F), lambda i: (i, 0))
    col = pl.BlockSpec((block_links, 1), lambda i: (i, 0))
    if weights.ndim == 1:  # per-flow vectors: one shared (1, F) row
        weights, backlog, rho = (
            a.reshape(1, F) for a in (weights, backlog, rho))
        flow = pl.BlockSpec((1, F), lambda i: (0, 0))
    else:
        flow = row
    return pl.pallas_call(
        functools.partial(_waterfill_block, dt=dt, block_flows=bf),
        grid=grid,
        in_specs=[flow, flow, flow, row, col, col],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((Lnum, F), jnp.float32),
        interpret=interpret,
    )(weights, backlog, rho, mask, cap2, kind2)
