"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128, SSD/state-space duality [arXiv:2405.21060; unverified].
d_inner = 2·d_model = 2048, head_dim 64 => 32 SSD heads."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("mamba2-370m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        rope_theta=None,
        tie_embeddings=True,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv=4,
        sub_quadratic=True,      # constant-state decode: long_500k runs
    )
