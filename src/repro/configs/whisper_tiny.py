"""whisper-tiny [audio] — enc-dec, 4L encoder + 4L decoder, d_model=384 6H
d_ff=1536 vocab=51865 [arXiv:2212.04356; unverified]. Conv/audio frontend is
a STUB (precomputed frame embeddings)."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("whisper-tiny")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,              # decoder layers
        n_enc_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab=51865,
        act="gelu",
        norm="layernorm",
        qkv_bias=True,
        rope_theta=None,         # sinusoidal positions
        tie_embeddings=True,
        sub_quadratic=False,
    )
