"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE, LayerNorm + GELU MLP with biases
[arXiv:2402.19173; hf]."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("starcoder2-15b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab=49152,
        head_dim=128,
        act="gelu",
        norm="layernorm",
        qkv_bias=True,
        rope_theta=1e5,
        tie_embeddings=True,
        sub_quadratic=False,
    )
