"""zamba2-1.2b [hybrid] — 38L d_model=2048 Mamba2 backbone + ONE shared
attention(32H, kv=32)+MLP(d_ff=8192) block applied every 6 layers,
ssm_state=64 [arXiv:2411.15242; hf]. Hybrid ⇒ long_500k decode runs
(SSM state constant; shared-attn KV is the only seq-length cache)."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("zamba2-1.2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,             # 6 groups of 6 SSM layers + 2 tail layers
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        rope_theta=10000.0,
        tie_embeddings=True,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv=4,
        hybrid_attn_every=6,
        sub_quadratic=True,
    )
