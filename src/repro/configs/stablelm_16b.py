"""stablelm-1.6b [dense] — 24L d_model=2048 32H (MHA kv=32) d_ff=5632
vocab=100352 [hf:stabilityai/stablelm-2-1_6b; unverified]. LayerNorm +
SwiGLU; partial-rotary detail simplified to full RoPE (noted in DESIGN.md)."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("stablelm-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab=100352,
        act="swiglu",
        norm="layernorm",
        rope_theta=10000.0,
        tie_embeddings=False,
        sub_quadratic=False,
    )
