"""The paper's own 'architecture': the stream-analytics testbed (§VI-A).

Not an LM — this config describes the cluster + workloads used by the
reproduction benchmarks: 10 machines (8 workers), a 1 GbE SDN switch
(big-switch model) and the fat-tree testbed (Fig. 2), the TT/TI apps, the
10/15/20 Mbps bottleneck settings, 600 s runs, Δt = 5 s.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StreamTestbedConfig:
    n_workers: int = 8
    caps_mbps: tuple = (10, 15, 20)
    mb_per_s: tuple = (1.25, 1.875, 2.5)
    seconds: float = 600.0
    dt: float = 0.5           # fluid tick
    alloc_interval_s: float = 5.0
    sample_hz: float = 1.0
    # fat-tree testbed (Fig. 2): 4 racks × 2 machines, 2 cores
    n_racks: int = 4
    machines_per_rack: int = 2
    n_cores: int = 2


def config() -> StreamTestbedConfig:
    return StreamTestbedConfig()
