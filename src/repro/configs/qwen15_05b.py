"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (MHA kv=16) d_ff=2816
vocab=151936, QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("qwen1.5-0.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab=151936,
        act="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,
        sub_quadratic=False,
    )
