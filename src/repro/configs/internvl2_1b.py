"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B-style LM backbone
[arXiv:2404.16821; hf]. 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. The vision frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (256 tokens) projected into the LM."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("internvl2-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151655,
        head_dim=64,
        act="swiglu",
        norm="rmsnorm",
        qkv_bias=True,           # Qwen2-style QKV bias
        rope_theta=1e6,
        tie_embeddings=True,
        n_vis_tokens=256,
        sub_quadratic=False,     # full attention -> long_500k skipped
    )
