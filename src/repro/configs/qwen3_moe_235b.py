"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert), vocab=151936, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
Qwen3 uses per-head q/k RMSNorm and no QKV bias."""
from repro.models.lm import ModelConfig
from repro.models.registry import register


@register("qwen3-moe-235b-a22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,               # fine-grained per-expert FFN width
        vocab=151936,
        head_dim=128,
        act="swiglu",
        norm="rmsnorm",
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=False,
        n_experts=128,
        top_k=8,
        sub_quadratic=False,
    )
