"""Synthetic data pipeline: deterministic, host-shardable token streams.

Two generators:
  * ``random``     — i.i.d. uniform tokens (throughput/dry-run work);
  * ``structured`` — a noisy affine-progression language (next ≈ a·cur+b
    mod V with replacement noise): has learnable structure, so example
    training runs show a visibly decreasing loss.

Sharding: each host materializes only its slice of the global batch
(``host_slice``), keyed by (seed, step, host_id) — restart-safe (the
pipeline is stateless; step index determines content, so checkpoint
restores resume the exact stream).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structured: bool = True
    noise: float = 0.1
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.host_id)
        B, S, V = self.host_batch, self.seq_len, self.vocab
        if not self.structured:
            tokens = rng.integers(0, V, (B, S + 1), dtype=np.int64)
        else:
            a = 31
            b = rng.integers(1, 17, (B, 1))
            t0 = rng.integers(0, V, (B, 1))
            # affine progression t_{i+1} = a·t_i + b (mod V), via closed form
            # t_i = a^i t_0 + b·(a^i − 1)/(a − 1) (mod V); powers iteratively
            ai = np.empty(S + 1, dtype=np.int64)
            ai[0] = 1
            for i in range(1, S + 1):
                ai[i] = (ai[i - 1] * a) % V
            ai = ai[None, :]
            inv = pow(a - 1, -1, V) if np.gcd(a - 1, V) == 1 else 1
            geo = ((ai - 1) * inv) % V
            tokens = (ai * t0 + geo * b) % V
            flip = rng.random((B, S + 1)) < self.noise
            tokens = np.where(flip, rng.integers(0, V, (B, S + 1)), tokens)
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def batches(self, n: int, start: int = 0):
        for i in range(start, start + n):
            yield self.batch(i)
