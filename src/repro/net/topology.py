"""Datacenter network model (paper §II-B, Fig. 2).

Links are unidirectional. Every machine has one *uplink* (machine -> rack
switch) and one *downlink* (rack switch -> machine). Multi-hop fabrics add
*internal* links (rack-to-core, core-to-rack). A flow (src machine, dst
machine) traverses: its uplink, zero or more internal links, and the
destination downlink. Internal flows (src == dst machine) traverse nothing.

Topology construction is static python/numpy; the resulting routing matrix
``R`` ([F, L] binary) and capacity vector feed the JAX solvers in
``repro.core``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np


class LinkKind(enum.IntEnum):
    UPLINK = 0
    DOWNLINK = 1
    INTERNAL = 2


@dataclasses.dataclass(frozen=True)
class Link:
    name: str
    kind: LinkKind
    capacity: float  # MB/s


@dataclasses.dataclass
class Topology:
    """A set of unidirectional links plus a routing function."""

    n_machines: int
    links: list[Link]
    # machine -> link index
    uplink_idx: np.ndarray
    downlink_idx: np.ndarray
    # rack topology metadata (empty for big-switch)
    rack_of: np.ndarray            # machine -> rack id
    rack_to_core_idx: np.ndarray   # [n_racks, n_cores] link index or -1
    core_to_rack_idx: np.ndarray   # [n_cores, n_racks] link index or -1
    n_cores: int = 0

    @property
    def n_links(self) -> int:
        return len(self.links)

    @property
    def capacities(self) -> np.ndarray:
        return np.array([l.capacity for l in self.links], dtype=np.float64)

    @property
    def link_kinds(self) -> np.ndarray:
        return np.array([int(l.kind) for l in self.links], dtype=np.int32)

    # ---- routing -----------------------------------------------------
    def core_for(self, src: int, dst: int) -> int:
        """ECMP-like deterministic core pick (paper notes ECMP is
        utilization/volume agnostic — which is what creates the internal
        bottlenecks §II-B discusses)."""
        return (src + dst) % max(self.n_cores, 1)

    def route(self, src: int, dst: int) -> list[int]:
        """Link indices traversed by flow src->dst (machines)."""
        if src == dst:
            return []  # internal flow: no network links
        path = [int(self.uplink_idx[src])]
        r_s, r_d = int(self.rack_of[src]), int(self.rack_of[dst])
        if self.n_cores > 0 and r_s != r_d:
            c = self.core_for(src, dst)
            path.append(int(self.rack_to_core_idx[r_s, c]))
            path.append(int(self.core_to_rack_idx[c, r_d]))
        path.append(int(self.downlink_idx[dst]))
        return path

    def routing_matrix(self, flows: Sequence[tuple[int, int]]) -> np.ndarray:
        """Binary R[f, l] = 1 iff flow f traverses link l (eq. 1a)."""
        R = np.zeros((len(flows), self.n_links), dtype=np.float64)
        for f, (s, d) in enumerate(flows):
            for l in self.route(s, d):
                R[f, l] = 1.0
        return R

    def set_capacity(self, kind: LinkKind, capacity: float) -> "Topology":
        """Return a copy with every link of ``kind`` re-capacitated (used to
        throttle internal links to shift the bottleneck, §VI-A.1)."""
        links = [
            Link(l.name, l.kind, capacity if l.kind == kind else l.capacity)
            for l in self.links
        ]
        return dataclasses.replace(self, links=links)


def big_switch(n_machines: int, up: float, down: float | None = None) -> Topology:
    """Paper's earlier model: fabric as one big non-blocking switch; only
    machine uplinks/downlinks can bottleneck (§II-B)."""
    down = up if down is None else down
    links: list[Link] = []
    upl = np.zeros(n_machines, dtype=np.int64)
    dnl = np.zeros(n_machines, dtype=np.int64)
    for m in range(n_machines):
        upl[m] = len(links)
        links.append(Link(f"up[m{m}]", LinkKind.UPLINK, up))
        dnl[m] = len(links)
        links.append(Link(f"down[m{m}]", LinkKind.DOWNLINK, down))
    return Topology(
        n_machines=n_machines,
        links=links,
        uplink_idx=upl,
        downlink_idx=dnl,
        rack_of=np.zeros(n_machines, dtype=np.int64),
        rack_to_core_idx=np.zeros((1, 0), dtype=np.int64),
        core_to_rack_idx=np.zeros((0, 1), dtype=np.int64),
        n_cores=0,
    )


def fat_tree(
    n_racks: int = 4,
    machines_per_rack: int = 2,
    n_cores: int = 2,
    up: float = 125.0,
    down: float | None = None,
    internal: float | None = None,
) -> Topology:
    """Fat-tree-like testbed (Fig. 2): with defaults, 8 machines, 8 uplinks,
    8 downlinks, 16 internal links (8 rack-to-core + 8 core-to-rack)."""
    down = up if down is None else down
    internal = up if internal is None else internal
    n_machines = n_racks * machines_per_rack
    links: list[Link] = []
    upl = np.zeros(n_machines, dtype=np.int64)
    dnl = np.zeros(n_machines, dtype=np.int64)
    rack_of = np.repeat(np.arange(n_racks), machines_per_rack)
    for m in range(n_machines):
        upl[m] = len(links)
        links.append(Link(f"up[m{m}]", LinkKind.UPLINK, up))
        dnl[m] = len(links)
        links.append(Link(f"down[m{m}]", LinkKind.DOWNLINK, down))
    r2c = -np.ones((n_racks, n_cores), dtype=np.int64)
    c2r = -np.ones((n_cores, n_racks), dtype=np.int64)
    for r in range(n_racks):
        for c in range(n_cores):
            r2c[r, c] = len(links)
            links.append(Link(f"r{r}->c{c}", LinkKind.INTERNAL, internal))
    for c in range(n_cores):
        for r in range(n_racks):
            c2r[c, r] = len(links)
            links.append(Link(f"c{c}->r{r}", LinkKind.INTERNAL, internal))
    return Topology(
        n_machines=n_machines,
        links=links,
        uplink_idx=upl,
        downlink_idx=dnl,
        rack_of=rack_of,
        rack_to_core_idx=r2c,
        core_to_rack_idx=c2r,
        n_cores=n_cores,
    )


def tpu_pod_fabric(
    n_pods: int,
    chips_per_pod: int,
    ici_gbps: float = 50.0,
    dcn_gbps: float = 6.25,
) -> Topology:
    """Abstract TPU fabric for the collective-flow scheduler: each chip's ICI
    injection modeled as its up/down link; pods joined by DCN 'cores'.

    This reuses the paper's fat-tree abstraction: chip<->pod-fabric links are
    up/down links; pod<->DCN links are internal. Capacities in GB/s treated as
    'MB/s × 1e3' — the solvers are unit-agnostic.
    """
    return fat_tree(
        n_racks=n_pods,
        machines_per_rack=chips_per_pod,
        n_cores=max(1, n_pods // 2) if n_pods > 1 else 1,
        up=ici_gbps * 1e3,
        internal=dcn_gbps * 1e3,
    )
