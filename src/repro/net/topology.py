"""Datacenter network model (paper §II-B, Fig. 2).

Links are unidirectional. Every machine has one *uplink* (machine -> rack
switch) and one *downlink* (rack switch -> machine). Multi-hop fabrics add
*internal* links (rack-to-core, core-to-rack). A flow (src machine, dst
machine) traverses: its uplink, zero or more internal links, and the
destination downlink. Internal flows (src == dst machine) traverse nothing.

Topology construction is static python/numpy; the resulting routing matrix
``R`` ([F, L] binary) and capacity vector feed the JAX solvers in
``repro.core``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np


class LinkKind(enum.IntEnum):
    UPLINK = 0
    DOWNLINK = 1
    INTERNAL = 2


@dataclasses.dataclass(frozen=True)
class Link:
    name: str
    kind: LinkKind
    capacity: float  # MB/s


@dataclasses.dataclass
class Topology:
    """A set of unidirectional links plus a routing function."""

    n_machines: int
    links: list[Link]
    # machine -> link index
    uplink_idx: np.ndarray
    downlink_idx: np.ndarray
    # rack topology metadata (empty for big-switch)
    rack_of: np.ndarray            # machine -> rack id
    rack_to_core_idx: np.ndarray   # [n_racks, n_cores] link index or -1
    core_to_rack_idx: np.ndarray   # [n_cores, n_racks] link index or -1
    n_cores: int = 0

    @property
    def n_links(self) -> int:
        return len(self.links)

    @property
    def capacities(self) -> np.ndarray:
        return np.array([l.capacity for l in self.links], dtype=np.float64)

    @property
    def link_kinds(self) -> np.ndarray:
        return np.array([int(l.kind) for l in self.links], dtype=np.int32)

    # ---- routing -----------------------------------------------------
    def core_for(self, src: int, dst: int) -> int:
        """ECMP-like deterministic core pick (paper notes ECMP is
        utilization/volume agnostic — which is what creates the internal
        bottlenecks §II-B discusses)."""
        return (src + dst) % max(self.n_cores, 1)

    def route(self, src: int, dst: int) -> list[int]:
        """Link indices traversed by flow src->dst (machines)."""
        if src == dst:
            return []  # internal flow: no network links
        path = [int(self.uplink_idx[src])]
        r_s, r_d = int(self.rack_of[src]), int(self.rack_of[dst])
        if self.n_cores > 0 and r_s != r_d:
            c = self.core_for(src, dst)
            path.append(int(self.rack_to_core_idx[r_s, c]))
            path.append(int(self.core_to_rack_idx[c, r_d]))
        path.append(int(self.downlink_idx[dst]))
        return path

    def route_avoiding(self, src: int, dst: int,
                       down: np.ndarray) -> "list[int] | None":
        """Shortest path src->dst that avoids ``down`` links ([L] bool).

        Up/down links have no alternates — if either endpoint link is down
        the flow has no surviving path (returns ``None``). Cross-rack flows
        choose among cores: every core path has the same hop count, so
        "shortest surviving" reduces to a core pick, and the existing ECMP
        choice (``core_for``) is the tie-break — surviving cores are tried
        in cyclic order starting from it, keeping rerouting deterministic
        and minimally disruptive (unaffected flows keep their ECMP core).
        """
        if src == dst:
            return []
        up, dn = int(self.uplink_idx[src]), int(self.downlink_idx[dst])
        if down[up] or down[dn]:
            return None
        r_s, r_d = int(self.rack_of[src]), int(self.rack_of[dst])
        if self.n_cores > 0 and r_s != r_d:
            c0 = self.core_for(src, dst)
            for k in range(self.n_cores):
                c = (c0 + k) % self.n_cores
                a = int(self.rack_to_core_idx[r_s, c])
                b = int(self.core_to_rack_idx[c, r_d])
                if a >= 0 and b >= 0 and not down[a] and not down[b]:
                    return [up, a, b, dn]
            return None
        return [up, dn]

    def routing_matrix(self, flows: Sequence[tuple[int, int]]) -> np.ndarray:
        """Binary R[f, l] = 1 iff flow f traverses link l (eq. 1a)."""
        R = np.zeros((len(flows), self.n_links), dtype=np.float64)
        for f, (s, d) in enumerate(flows):
            for l in self.route(s, d):
                R[f, l] = 1.0
        return R

    def set_capacity(self, kind: LinkKind, capacity: float) -> "Topology":
        """Return a copy with every link of ``kind`` re-capacitated (used to
        throttle internal links to shift the bottleneck, §VI-A.1)."""
        links = [
            Link(l.name, l.kind, capacity if l.kind == kind else l.capacity)
            for l in self.links
        ]
        return dataclasses.replace(self, links=links)


# --------------------------------------------------------------------------
# time-varying link capacities
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LinkSchedule:
    """Compact in-run capacity schedule: ``caps(t)`` per link.

    The simulator evaluates, per tick,

        caps_l(t) = base_l · (1 + Σ_s amp[s,l]·sin(omega[s,l]·t + phase[s,l]))
                           · Π_{e active at t, link_e = l} scale_e

    clipped at zero. Two compact array families cover the paper's in-run
    regimes (Fig. 5/12 transients):

      * **sinusoids** ``[S, L]`` — diurnal-style smooth cycles (S basis
        components; S = 0 means none and the simulator skips the term by
        *shape*, so static runs pay nothing);
      * **events** ``[E]`` — piecewise-constant multiplicative steps
        ``scale_e`` on link ``link_e`` over ``[t0_e, t1_e)``: link
        failures (scale 0), brown-outs (0 < scale < 1), and recoveries
        (the event simply ends). E = 0 likewise skips by shape.

    Both families batch and pad like any other fleet field: padded
    sinusoid rows have zero amplitude, padded events never activate
    (``t0 = inf``) — a padded schedule is bitwise-neutral.
    """

    n_links: int
    sin_amp: np.ndarray     # [S, L]
    sin_omega: np.ndarray   # [S, L] rad/s
    sin_phase: np.ndarray   # [S, L] rad
    ev_t0: np.ndarray       # [E] s (event active while t0 <= t < t1)
    ev_t1: np.ndarray       # [E] s
    ev_link: np.ndarray     # [E] int32 link index
    ev_scale: np.ndarray    # [E] capacity multiplier while active

    @classmethod
    def constant(cls, n_links: int) -> "LinkSchedule":
        """A schedule that never changes anything — but *does* exercise the
        dynamic evaluation path (one zero-amplitude sinusoid and one never-
        active event), so it serves as the static-parity oracle."""
        z = np.zeros((1, n_links), np.float32)
        return cls(
            n_links=n_links, sin_amp=z, sin_omega=z.copy(),
            sin_phase=z.copy(),
            ev_t0=np.full((1,), np.inf, np.float32),
            ev_t1=np.full((1,), np.inf, np.float32),
            ev_link=np.zeros((1,), np.int32),
            ev_scale=np.ones((1,), np.float32),
        )

    @classmethod
    def empty(cls, n_links: int) -> "LinkSchedule":
        """No components at all (S = 0, E = 0): identical to passing no
        schedule — the simulator skips every dynamic term by shape."""
        z = np.zeros((0, n_links), np.float32)
        e = np.zeros((0,), np.float32)
        return cls(n_links=n_links, sin_amp=z, sin_omega=z.copy(),
                   sin_phase=z.copy(), ev_t0=e, ev_t1=e.copy(),
                   ev_link=e.astype(np.int32), ev_scale=e.copy())

    # ---- builders (functional: each returns a new schedule) ----------
    def with_event(self, link_ids, t0: float, t1: float = np.inf,
                   scale: float = 0.0) -> "LinkSchedule":
        """Scale the given links' capacity by ``scale`` over ``[t0, t1)``
        (scale 0 = hard failure; the link recovers at ``t1``)."""
        ids = np.atleast_1d(np.asarray(link_ids, np.int32))
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_links):
            raise ValueError(
                f"event link ids {ids} out of range for {self.n_links} links")
        return dataclasses.replace(
            self,
            ev_t0=np.concatenate(
                [self.ev_t0, np.full(ids.shape, t0, np.float32)]),
            ev_t1=np.concatenate(
                [self.ev_t1, np.full(ids.shape, t1, np.float32)]),
            ev_link=np.concatenate([self.ev_link, ids]),
            ev_scale=np.concatenate(
                [self.ev_scale, np.full(ids.shape, scale, np.float32)]),
        )

    def with_diurnal(self, period_s: float, amplitude: float,
                     link_ids=None, phase: float = 0.0) -> "LinkSchedule":
        """Add a sinusoidal capacity cycle on ``link_ids`` (default: every
        link): caps ·= 1 + amplitude·sin(2π t / period + phase)."""
        amp = np.zeros((1, self.n_links), np.float32)
        if link_ids is None:
            amp[0, :] = amplitude
        else:
            amp[0, np.asarray(link_ids, np.int64)] = amplitude
        omega = np.full((1, self.n_links), 2.0 * np.pi / period_s, np.float32)
        ph = np.full((1, self.n_links), phase, np.float32)
        return dataclasses.replace(
            self,
            sin_amp=np.concatenate([self.sin_amp, amp]),
            sin_omega=np.concatenate([self.sin_omega, omega]),
            sin_phase=np.concatenate([self.sin_phase, ph]),
        )

    # ---- host-side evaluation (numpy reference / plotting) -----------
    def caps_at(self, base: np.ndarray, t) -> np.ndarray:
        """Evaluate caps(t) in numpy. ``t`` scalar or [T]; returns [L] or
        [T, L]. The JAX evaluation in the simulator must match this."""
        t = np.asarray(t, np.float64)
        scalar = t.ndim == 0
        ts = np.atleast_1d(t)
        caps = np.broadcast_to(np.asarray(base, np.float64)[None, :],
                               (ts.shape[0], self.n_links)).copy()
        if self.sin_amp.shape[0]:
            wave = np.sum(
                self.sin_amp[None] * np.sin(
                    self.sin_omega[None] * ts[:, None, None]
                    + self.sin_phase[None]), axis=1)
            caps *= 1.0 + wave
        # Event activity is decided in float32, exactly like the compiled
        # `_caps_over` path: event times are stored as float32, so deciding
        # `t >= t0` in float64 flips the half-open [t0, t1) boundary for
        # any t0/t1 that float32 rounds upward (e.g. t0 = 0.1 — the f64
        # query 0.1 lands *below* the stored f32 0.10000000149). Comparing
        # at f32 precision keeps t == t0 active and t == t1 inactive on
        # both sides for every representable query time.
        ts32 = ts.astype(np.float32)
        for e in range(self.ev_t0.shape[0]):
            active = (ts32 >= self.ev_t0[e]) & (ts32 < self.ev_t1[e])
            caps[:, int(self.ev_link[e])] *= np.where(
                active, float(self.ev_scale[e]), 1.0)
        caps = np.maximum(caps, 0.0)
        return caps[0] if scalar else caps


# --------------------------------------------------------------------------
# mid-run rerouting
# --------------------------------------------------------------------------
# A link whose composed event multiplier drops below this is treated as
# *failed for routing*: the SDN controller reroutes around hard failures
# (scale 0) and deep brown-outs, but not mild degradations or the smooth
# sinusoid components (a controller does not flap routes on diurnal load).
ROUTE_DOWN_THRESHOLD = 0.5


@dataclasses.dataclass(frozen=True)
class RouteSchedule:
    """Precompiled mid-run rerouting: ``R(t)`` as a bank of route states.

    The event schedule partitions time into intervals on which the set of
    active events — hence the set of routing-failed links — is constant.
    Each distinct failed-link combination is one *route state* with its own
    rerouted routing matrix; the number of states is bounded by the number
    of event boundaries (≤ 2·E + 1, typically 2–4), so the whole bank
    precompiles into one ``[S_r, F, L]`` operand the simulator gathers from
    inside the scan — no recompilation, no ``lax.cond``.

    Flows with no surviving path keep their dead base route (they move no
    bytes through a hard-failed link, exactly like today's capacity-only
    failures); everything else takes the shortest surviving path with the
    ECMP core pick as tie-break (see :meth:`Topology.route_avoiding`).
    """

    t0: np.ndarray      # [K] f32 interval start times, t0[0] == 0.0
    state: np.ndarray   # [K] int32 route-state index per interval
    routes: np.ndarray  # [S, F, L] f32 binary routing matrix per state
    down: np.ndarray    # [S, L] bool, links treated as failed per state

    @property
    def n_states(self) -> int:
        return self.routes.shape[0]

    @property
    def n_intervals(self) -> int:
        return self.t0.shape[0]

    @classmethod
    def from_events(cls, topo: "Topology",
                    flows: Sequence[tuple[int, int]],
                    schedule: "LinkSchedule",
                    threshold: float = ROUTE_DOWN_THRESHOLD,
                    ) -> "RouteSchedule":
        """Enumerate reachable route states from ``schedule``'s events."""
        F, L = len(flows), topo.n_links
        base_R = topo.routing_matrix(flows).astype(np.float32)
        t0e = np.asarray(schedule.ev_t0, np.float32)
        t1e = np.asarray(schedule.ev_t1, np.float32)
        bounds = np.concatenate([[0.0], t0e[np.isfinite(t0e)],
                                 t1e[np.isfinite(t1e)]]).astype(np.float32)
        bounds = np.unique(bounds[bounds >= 0.0])
        key_to_state: dict[bytes, int] = {}
        state_of, routes_list, down_list = [], [], []
        for tb in bounds:
            # same f32 half-open [t0, t1) activity rule as caps_at/_caps_over
            active = (tb >= t0e) & (tb < t1e)
            scale = np.ones(L, np.float64)
            for e in np.flatnonzero(active):
                scale[int(schedule.ev_link[e])] *= float(schedule.ev_scale[e])
            dwn = scale < threshold
            key = dwn.tobytes()
            if key not in key_to_state:
                key_to_state[key] = len(routes_list)
                R = base_R.copy()
                for f, (s, d) in enumerate(flows):
                    p = topo.route_avoiding(s, d, dwn)
                    if p is not None:
                        R[f] = 0.0
                        R[f, p] = 1.0
                routes_list.append(R)
                down_list.append(dwn)
            state_of.append(key_to_state[key])
        return cls(
            t0=bounds.astype(np.float32),
            state=np.asarray(state_of, np.int32),
            routes=np.stack(routes_list).astype(np.float32),
            down=np.stack(down_list),
        )

    # ---- host-side evaluation (numpy reference) ----------------------
    def state_at(self, t) -> int:
        """Route-state index active at time ``t`` (f32 comparison, matching
        the compiled per-tick state stream)."""
        t32 = np.float32(t)
        j = int(np.sum(t32 >= self.t0)) - 1
        return int(self.state[max(j, 0)])

    def routes_at(self, t) -> np.ndarray:
        """Routing matrix [F, L] active at time ``t`` (numpy reference for
        the compiled in-scan gather)."""
        return self.routes[self.state_at(t)]


def link_failure_schedule(topo: "Topology", link_ids, t_fail: float,
                          t_recover: float = np.inf,
                          degrade: float = 0.0) -> LinkSchedule:
    """Mid-run failure (or brown-out, ``0 < degrade < 1``) of the given
    links at ``t_fail``, recovering at ``t_recover``."""
    return LinkSchedule.empty(topo.n_links).with_event(
        link_ids, t_fail, t_recover, degrade)


def diurnal_schedule(topo: "Topology", period_s: float, amplitude: float,
                     kind: "LinkKind | None" = None,
                     phase: float = 0.0) -> LinkSchedule:
    """Sinusoidal capacity cycle over every link (or every link of one
    ``kind``): the in-run version of the quasi-static diurnal sweep."""
    ids = None
    if kind is not None:
        ids = np.flatnonzero(topo.link_kinds == int(kind))
    return LinkSchedule.empty(topo.n_links).with_diurnal(
        period_s, amplitude, link_ids=ids, phase=phase)


def big_switch(n_machines: int, up: float, down: float | None = None) -> Topology:
    """Paper's earlier model: fabric as one big non-blocking switch; only
    machine uplinks/downlinks can bottleneck (§II-B)."""
    down = up if down is None else down
    links: list[Link] = []
    upl = np.zeros(n_machines, dtype=np.int64)
    dnl = np.zeros(n_machines, dtype=np.int64)
    for m in range(n_machines):
        upl[m] = len(links)
        links.append(Link(f"up[m{m}]", LinkKind.UPLINK, up))
        dnl[m] = len(links)
        links.append(Link(f"down[m{m}]", LinkKind.DOWNLINK, down))
    return Topology(
        n_machines=n_machines,
        links=links,
        uplink_idx=upl,
        downlink_idx=dnl,
        rack_of=np.zeros(n_machines, dtype=np.int64),
        rack_to_core_idx=np.zeros((1, 0), dtype=np.int64),
        core_to_rack_idx=np.zeros((0, 1), dtype=np.int64),
        n_cores=0,
    )


def fat_tree(
    n_racks: int = 4,
    machines_per_rack: int = 2,
    n_cores: int = 2,
    up: float = 125.0,
    down: float | None = None,
    internal: float | None = None,
) -> Topology:
    """Fat-tree-like testbed (Fig. 2): with defaults, 8 machines, 8 uplinks,
    8 downlinks, 16 internal links (8 rack-to-core + 8 core-to-rack)."""
    down = up if down is None else down
    internal = up if internal is None else internal
    n_machines = n_racks * machines_per_rack
    links: list[Link] = []
    upl = np.zeros(n_machines, dtype=np.int64)
    dnl = np.zeros(n_machines, dtype=np.int64)
    rack_of = np.repeat(np.arange(n_racks), machines_per_rack)
    for m in range(n_machines):
        upl[m] = len(links)
        links.append(Link(f"up[m{m}]", LinkKind.UPLINK, up))
        dnl[m] = len(links)
        links.append(Link(f"down[m{m}]", LinkKind.DOWNLINK, down))
    r2c = -np.ones((n_racks, n_cores), dtype=np.int64)
    c2r = -np.ones((n_cores, n_racks), dtype=np.int64)
    for r in range(n_racks):
        for c in range(n_cores):
            r2c[r, c] = len(links)
            links.append(Link(f"r{r}->c{c}", LinkKind.INTERNAL, internal))
    for c in range(n_cores):
        for r in range(n_racks):
            c2r[c, r] = len(links)
            links.append(Link(f"c{c}->r{r}", LinkKind.INTERNAL, internal))
    return Topology(
        n_machines=n_machines,
        links=links,
        uplink_idx=upl,
        downlink_idx=dnl,
        rack_of=rack_of,
        rack_to_core_idx=r2c,
        core_to_rack_idx=c2r,
        n_cores=n_cores,
    )


def tpu_pod_fabric(
    n_pods: int,
    chips_per_pod: int,
    ici_gbps: float = 50.0,
    dcn_gbps: float = 6.25,
) -> Topology:
    """Abstract TPU fabric for the collective-flow scheduler: each chip's ICI
    injection modeled as its up/down link; pods joined by DCN 'cores'.

    This reuses the paper's fat-tree abstraction: chip<->pod-fabric links are
    up/down links; pod<->DCN links are internal. Capacities in GB/s treated as
    'MB/s × 1e3' — the solvers are unit-agnostic.
    """
    return fat_tree(
        n_racks=n_pods,
        machines_per_rack=chips_per_pod,
        n_cores=max(1, n_pods // 2) if n_pods > 1 else 1,
        up=ici_gbps * 1e3,
        internal=dcn_gbps * 1e3,
    )
