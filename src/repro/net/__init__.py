from repro.net.topology import (  # noqa: F401
    Link,
    LinkKind,
    LinkSchedule,
    RouteSchedule,
    Topology,
    big_switch,
    diurnal_schedule,
    fat_tree,
    link_failure_schedule,
    tpu_pod_fabric,
)
