from repro.net.topology import (  # noqa: F401
    Link,
    LinkKind,
    Topology,
    big_switch,
    fat_tree,
    tpu_pod_fabric,
)
