"""Stream-application model (paper §II-A): logical DAG of operators,
parallelized into instances, with grouping policies (shuffle / key-based /
global / all) determining the inter-instance flow graph.

The compiled form is a set of static matrices consumed by the fluid
simulator (`repro.streams.simulator`) and by the allocator's routing
program. Everything here is plain python/numpy — it runs once per topology.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class Grouping(enum.Enum):
    SHUFFLE = "shuffle"     # round-robin: even split across dst instances
    KEY = "key"             # hash-partition: skewed split (Zipf over keys)
    GLOBAL = "global"       # all tuples to dst instance 0
    ALL = "all"             # broadcast: full stream to every dst instance


@dataclasses.dataclass(frozen=True)
class Operator:
    """A logical operator (vertex). Rates in MB/s of *input* consumed.

    selectivity: MB emitted per MB consumed (source ops: ignored).
    gen_rate:    MB/s generated externally (only source ops, else 0).
    join:        m:1 lock-step join — processing advances at the rate of the
                 slowest *proportional* input (the paper's stall mechanism).
    """

    name: str
    parallelism: int = 1
    proc_rate: float = np.inf
    selectivity: float = 1.0
    gen_rate: float = 0.0
    join: bool = False

    @property
    def is_source(self) -> bool:
        return self.gen_rate > 0.0


@dataclasses.dataclass(frozen=True)
class Edge:
    src: str
    dst: str
    grouping: Grouping = Grouping.SHUFFLE
    weight: float = 1.0      # fraction of src output onto this logical edge
    key_skew: float = 0.0    # Zipf exponent for KEY grouping (0 = uniform)
    # lock-step joins: semantic share of the dst's joined input taken from
    # this edge (e.g. each truck event joins the LATEST congestion record —
    # the congestion stream is oversampled). None => proportional to volume.
    join_share: float | None = None
    # excess tuples beyond the join's working window are discarded at the
    # receiver (stale data); their bandwidth is *wasted* — the paper's TCP
    # inefficiency mechanism for TI.
    droppable: bool = False


@dataclasses.dataclass
class StreamApp:
    """Logical topology (e.g. Fig. 1a / Fig. 7)."""

    name: str
    operators: list[Operator]
    edges: list[Edge]
    tuples_per_mb: float = 2000.0   # avg tuple size ⇒ MB → tuples conversion

    def op(self, name: str) -> Operator:
        return next(o for o in self.operators if o.name == name)

    def validate(self) -> None:
        names = [o.name for o in self.operators]
        assert len(set(names)) == len(names), "duplicate operator names"
        for e in self.edges:
            assert e.src in names and e.dst in names, f"dangling edge {e}"
        out_w: dict[str, float] = {}
        for e in self.edges:
            out_w[e.src] = out_w.get(e.src, 0.0) + e.weight
        for k, w in out_w.items():
            assert w <= 1.0 + 1e-6, f"{k} emits {w} > 1 of its output"


@dataclasses.dataclass
class InstanceGraph:
    """Parallelized topology: one node per operator instance, one flow per
    communicating instance pair (paper §II-C)."""

    app: StreamApp
    op_of_inst: np.ndarray           # [I] operator index
    inst_names: list[str]
    # flows
    src_of_flow: np.ndarray          # [F] instance index
    dst_of_flow: np.ndarray          # [F]
    edge_of_flow: np.ndarray         # [F] logical edge index
    w_out: np.ndarray                # [I, F] fraction of inst output on flow
    # instance attributes (expanded from operators)
    proc_rate: np.ndarray            # [I]
    selectivity: np.ndarray          # [I]
    gen_rate: np.ndarray             # [I]
    is_join: np.ndarray              # [I] bool
    is_sink: np.ndarray              # [I] bool

    @property
    def n_instances(self) -> int:
        return len(self.op_of_inst)

    @property
    def n_flows(self) -> int:
        return len(self.src_of_flow)

    def in_matrix(self) -> np.ndarray:
        """M[i, f] = 1 iff flow f terminates at instance i."""
        M = np.zeros((self.n_instances, self.n_flows))
        M[self.dst_of_flow, np.arange(self.n_flows)] = 1.0
        return M

    def flow_pairs(self, machine_of_inst: np.ndarray) -> list[tuple[int, int]]:
        """(src machine, dst machine) per flow, given a placement."""
        return [
            (int(machine_of_inst[s]), int(machine_of_inst[d]))
            for s, d in zip(self.src_of_flow, self.dst_of_flow)
        ]


def _split_weights(grouping: Grouping, n_dst: int, skew: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Fraction of the edge's traffic received by each dst instance."""
    if grouping is Grouping.SHUFFLE or n_dst == 1:
        w = np.full(n_dst, 1.0 / n_dst)
    elif grouping is Grouping.GLOBAL:
        w = np.zeros(n_dst)
        w[0] = 1.0
    elif grouping is Grouping.ALL:
        w = np.ones(n_dst)  # broadcast: each dst gets the FULL stream
    elif grouping is Grouping.KEY:
        # hash partitioning roughly even-partitions the key space, but skewed
        # key popularity (heavy tails) imbalances bytes (paper §II-A.3b)
        ranks = np.arange(1, n_dst + 1, dtype=np.float64)
        w = ranks ** (-skew) if skew > 0 else np.ones(n_dst)
        rng.shuffle(w)
        w = w / w.sum()
    else:  # pragma: no cover
        raise ValueError(grouping)
    return w


def parallelize(app: StreamApp, seed: int = 0) -> InstanceGraph:
    """Expand the logical DAG into the instance-level flow graph (Fig. 1b)."""
    app.validate()
    rng = np.random.default_rng(seed)
    op_index = {o.name: k for k, o in enumerate(app.operators)}
    inst_of_op: dict[str, list[int]] = {}
    op_of_inst: list[int] = []
    names: list[str] = []
    for o in app.operators:
        ids = []
        for r in range(o.parallelism):
            ids.append(len(op_of_inst))
            op_of_inst.append(op_index[o.name])
            names.append(f"{o.name}_{r + 1}")
        inst_of_op[o.name] = ids

    srcs, dsts, fracs, eids = [], [], [], []
    for ei, e in enumerate(app.edges):
        s_ids = inst_of_op[e.src]
        d_ids = inst_of_op[e.dst]
        w_dst = _split_weights(e.grouping, len(d_ids), e.key_skew, rng)
        for si in s_ids:
            for dj, wd in zip(d_ids, w_dst):
                if wd <= 0.0:
                    continue
                srcs.append(si)
                dsts.append(dj)
                fracs.append(e.weight * wd)
                eids.append(ei)

    I, F = len(op_of_inst), len(srcs)
    w_out = np.zeros((I, F))
    w_out[np.array(srcs), np.arange(F)] = np.array(fracs)

    ops = app.operators
    has_out = {e.src for e in app.edges}
    return InstanceGraph(
        app=app,
        op_of_inst=np.array(op_of_inst),
        inst_names=names,
        src_of_flow=np.array(srcs, dtype=np.int64),
        dst_of_flow=np.array(dsts, dtype=np.int64),
        edge_of_flow=np.array(eids, dtype=np.int64),
        w_out=w_out,
        proc_rate=np.array([ops[k].proc_rate for k in op_of_inst]),
        selectivity=np.array([ops[k].selectivity for k in op_of_inst]),
        gen_rate=np.array(
            [ops[k].gen_rate / ops[k].parallelism for k in op_of_inst]
        ),
        is_join=np.array([ops[k].join for k in op_of_inst]),
        is_sink=np.array(
            [ops[k].name not in has_out for k in op_of_inst]
        ),
    )


def source_sink_paths(graph: InstanceGraph, max_paths: int = 64) -> np.ndarray:
    """Binary masks [P, F]: flows along each source→sink instance path
    (used for the end-to-end latency estimate)."""
    I = graph.n_instances
    out_flows: list[list[int]] = [[] for _ in range(I)]
    for f, s in enumerate(graph.src_of_flow):
        out_flows[int(s)].append(f)
    paths: list[list[int]] = []

    def dfs(i: int, acc: list[int]):
        if len(paths) >= max_paths:
            return
        if graph.is_sink[i]:
            paths.append(list(acc))
            return
        for f in out_flows[i]:
            dfs(int(graph.dst_of_flow[f]), acc + [f])

    for i in range(I):
        if graph.gen_rate[i] > 0:
            dfs(i, [])
    P = np.zeros((max(len(paths), 1), graph.n_flows))
    for p, fl in enumerate(paths):
        P[p, fl] = 1.0
    return P
