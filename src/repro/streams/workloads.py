"""The paper's test applications (§VI-A.2, Fig. 7) plus the motivating
examples (Fig. 1, Fig. 3), as synthetic workload generators.

Rates are calibrated so that derived-tuple rates exceed the provisioned
bandwidth (1.25–2.5 MB/s ≙ the paper's 10–20 Mbps), i.e. the network — not
CPU — is the bottleneck, matching the paper's data-intensive regime. The
real Twitter/IoT datasets are unavailable offline; generators preserve the
statistical shape the paper describes (arrival rates, tuple-size imbalance,
key skew).
"""
from __future__ import annotations

from repro.streams.app import Edge, Grouping, Operator, StreamApp

# 10 Mbps / 15 Mbps / 20 Mbps in MB/s — the paper's three settings
PAPER_CAPS_MBPS = {"10Mbps": 1.25, "15Mbps": 1.875, "20Mbps": 2.5}


def trending_topics(parallelism: int = 2, n_wct: int = 4,
                    tweets_per_sec: float = 1200.0) -> StreamApp:
    """TT (Fig. 7 top): source → splitter → word-count (key-grouped, skewed)
    → top-K aggregator (windowed join over all WCT partitions) → report.

    1000 tweets/s (paper), ~1 KB avg emitted tuple. Key skew imbalances the
    WCT→aggregator flows; the aggregator needs *all* partitions per window,
    so TCP's equal split stalls it on the heavy partition (paper §VI-B).
    """
    gen_mb = tweets_per_sec / 1000.0  # 1 KB per tweet-tuple
    return StreamApp(
        name="trending_topics",
        operators=[
            Operator("source", parallelism, gen_rate=gen_mb, proc_rate=100.0),
            Operator("splitter", parallelism, proc_rate=100.0, selectivity=2.5),
            Operator("wct", n_wct, proc_rate=100.0, selectivity=0.8),
            Operator("aggregator", 1, proc_rate=50.0, selectivity=0.05, join=True),
            Operator("report", 1, proc_rate=50.0, selectivity=0.0),
        ],
        edges=[
            Edge("source", "splitter", Grouping.SHUFFLE),
            Edge("splitter", "wct", Grouping.KEY, key_skew=0.35),
            Edge("wct", "aggregator", Grouping.GLOBAL),
            Edge("aggregator", "report", Grouping.GLOBAL),
        ],
        tuples_per_mb=1000.0,
    )


def trucking_iot(parallelism: int = 2) -> StreamApp:
    """TI (Fig. 7 bottom): two sources with very different tuple sizes
    (heavy truck telemetry vs chatty traffic-congestion updates, paper
    §VI-A.2) parsed and combined by a lock-step join. Under TCP the heavy
    truck flow is throttled by the very frequent small-tuple flow; the
    combiner stalls waiting for truck data (paper §VI-B)."""
    truck_mb = 400.0 * 8e-3      # 3.2 MB/s of heavy telemetry tuples
    traffic_mb = 1250.0 * 1e-3   # 1.25 MB/s of chatty congestion updates
    return StreamApp(
        name="trucking_iot",
        operators=[
            Operator("truck_src", parallelism, gen_rate=truck_mb, proc_rate=100.0),
            Operator("traffic_src", parallelism, gen_rate=traffic_mb, proc_rate=100.0),
            Operator("truck_parse", parallelism, proc_rate=100.0, selectivity=1.0),
            Operator("traffic_parse", parallelism, proc_rate=100.0, selectivity=1.0),
            Operator("combiner", 1, proc_rate=50.0, selectivity=0.2, join=True),
            Operator("sink", 1, proc_rate=50.0, selectivity=0.0),
        ],
        edges=[
            Edge("truck_src", "truck_parse", Grouping.SHUFFLE),
            Edge("traffic_src", "traffic_parse", Grouping.SHUFFLE),
            Edge("truck_parse", "combiner", Grouping.GLOBAL),
            # each truck event joins with the LATEST congestion record: the
            # congestion stream is oversampled — only ~35% of the joined
            # input is congestion bytes; stale records are discarded at the
            # combiner (TCP keeps shipping them anyway).
            Edge("traffic_parse", "combiner", Grouping.GLOBAL,
                 join_share=0.35, droppable=True),
            Edge("combiner", "sink", Grouping.GLOBAL),
        ],
        tuples_per_mb=300.0,
    )


def linkedin_tags() -> StreamApp:
    """Fig. 1: the LinkedIn trending-tags example (Split → Skill/Job
    extractors → Merge → Count → TopK), parallelism 2 except the sink."""
    return StreamApp(
        name="linkedin_tags",
        operators=[
            Operator("split", 2, gen_rate=1.0, proc_rate=100.0),
            Operator("skill_extract", 2, proc_rate=100.0, selectivity=0.9),
            Operator("job_extract", 2, proc_rate=100.0, selectivity=0.9),
            Operator("merge", 2, proc_rate=100.0, selectivity=1.0, join=True),
            Operator("count", 2, proc_rate=100.0, selectivity=0.5),
            Operator("topk", 1, proc_rate=50.0, selectivity=0.0, join=True),
        ],
        edges=[
            Edge("split", "skill_extract", Grouping.SHUFFLE, weight=0.5),
            Edge("split", "job_extract", Grouping.SHUFFLE, weight=0.5),
            Edge("skill_extract", "merge", Grouping.KEY, key_skew=0.8, weight=1.0),
            Edge("job_extract", "merge", Grouping.KEY, key_skew=0.8, weight=1.0),
            Edge("merge", "count", Grouping.KEY, key_skew=0.6),
            Edge("count", "topk", Grouping.GLOBAL),
        ],
        tuples_per_mb=2000.0,
    )


def motivation_chain() -> StreamApp:
    """Fig. 3 micro-study: 4 operators, parallelism 1. Differing
    selectivities make the three flows' volumes unequal, so the right split
    of a shared uplink is *not* TCP's 50/50."""
    return StreamApp(
        name="motivation",
        operators=[
            Operator("src", 1, gen_rate=2.0, proc_rate=100.0),
            Operator("opA", 1, proc_rate=100.0, selectivity=0.6),
            Operator("opB", 1, proc_rate=100.0, selectivity=0.5),
            Operator("sink", 1, proc_rate=50.0, selectivity=0.0),
        ],
        edges=[
            Edge("src", "opA", Grouping.GLOBAL),
            Edge("opA", "opB", Grouping.GLOBAL),
            Edge("opB", "sink", Grouping.GLOBAL),
        ],
        tuples_per_mb=1000.0,
    )


WORKLOADS = {
    "TT": trending_topics,
    "TI": trucking_iot,
    "tags": linkedin_tags,
    "motivation": motivation_chain,
}
