from repro.streams.app import (  # noqa: F401
    Edge,
    Grouping,
    InstanceGraph,
    Operator,
    StreamApp,
    parallelize,
    source_sink_paths,
)
from repro.streams.faults import (  # noqa: F401
    FailureRecord,
    FaultAbort,
    FaultPlan,
    FaultSpec,
    InjectedFault,
)
from repro.streams.fleet import (  # noqa: F401
    CampaignResult,
    FleetRunner,
    FleetShape,
    pad_sim,
    simulate_many,
    stack_sims,
)
from repro.streams.placement import STRATEGIES, round_robin, packed, traffic_aware  # noqa: F401
from repro.streams.scenarios import (  # noqa: F401
    Scenario,
    bench_fleet,
    campaign_fleet,
    capacity_sweep,
    compile_fleet,
    link_failure_sweep,
    random_app,
    random_scenarios,
    seed_fleet,
    time_varying_sweep,
)
from repro.streams.simulator import (  # noqa: F401
    CAMPAIGN_METRICS,
    CompiledSim,
    SimResult,
    compile_sim,
    metric_index,
    simulate,
)
from repro.streams.workloads import (  # noqa: F401
    PAPER_CAPS_MBPS,
    WORKLOADS,
    linkedin_tags,
    motivation_chain,
    trending_topics,
    trucking_iot,
)
