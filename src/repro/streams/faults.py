"""Deterministic fault injection for the streaming campaign runtime.

The paper treats runtime failure as a first-class event — links die
mid-run, controllers re-solve, the system degrades and recovers — and the
campaign pipeline (``FleetRunner.run_campaign``) inherits that premise on
the *harness* side: a transfer worker can throw, a preemptible device can
hang an H2D copy, one scenario out of 10⁴ can NaN-poison its metric row.
Every one of those recovery paths must be **testable on demand**, not
hoped-for, so this module provides an injectable, seeded
:class:`FaultPlan` the campaign loop consults at each pipeline stage:

* ``"pack"``   — host staging of a chunk raises before the slot is filled;
* ``"transfer"`` — the H2D worker raises (or, with ``hang_s``, sleeps —
  exercising the ``transfer_timeout_s`` watchdog instead of the retry
  path);
* ``"dispatch"`` — the compiled executable's launch raises;
* ``"abort"``  — a :class:`FaultAbort` (a ``BaseException``, so no retry
  handler can swallow it) kills the campaign mid-stream, simulating a
  preemption/SIGKILL for checkpoint-resume tests;
* *poisoned scenarios* — the listed scenario indices get their
  ``[n_metrics]`` epilogue row overwritten with NaN at every collection,
  so the poison deterministically **follows the scenario** through chunk
  retries and bisection, exactly like a genuinely NaN-producing run would.

Faults are consumed deterministically: a :class:`FaultSpec` with
``times=2`` fires on the first two matching stage visits (wherever they
happen — pipeline attempt, retry, bisected sub-run) and then never again,
which is what makes "transient failure → retry succeeds" a reproducible
test instead of a race. ``times=-1`` fires forever (a permanently broken
stage). All injection state is behind a lock — the transfer stage fires
on the worker thread.

Nothing here touches the compiled executables: injection happens in the
host-side pipeline only, so a run with ``faults=None`` is byte-for-byte
the unfaulted campaign path.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Iterable, Sequence

import numpy as np

#: pipeline stages a FaultSpec may target (in pipeline order)
FAULT_STAGES = ("pack", "transfer", "dispatch", "abort")


class InjectedFault(RuntimeError):
    """A deterministic, injected pipeline failure (retryable)."""

    def __init__(self, stage: str, chunk: int):
        super().__init__(f"injected {stage} fault (chunk {chunk})")
        self.stage = stage
        self.chunk = chunk


class FaultAbort(BaseException):
    """Injected mid-campaign kill. Deliberately a ``BaseException`` (like
    ``KeyboardInterrupt``): the campaign's retry machinery catches
    ``Exception`` only, so an abort always propagates through the
    teardown path — the closest in-process stand-in for a preemption."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injectable failure.

    ``chunk`` is the campaign *job index* to target (``None`` = any
    chunk); ``times`` is how many matching stage visits fire before the
    spec is spent (``-1`` = every visit — a permanent fault); a nonzero
    ``hang_s`` makes the visit *sleep* instead of raising, which is how
    the transfer watchdog (``transfer_timeout_s``) gets exercised."""

    stage: str
    chunk: int | None = None
    times: int = 1
    hang_s: float = 0.0

    def __post_init__(self):
        if self.stage not in FAULT_STAGES:
            raise ValueError(
                f"unknown fault stage {self.stage!r}; expected one of "
                f"{FAULT_STAGES}")
        if self.times == 0 or self.times < -1:
            raise ValueError(f"times must be positive or -1, got {self.times}")
        if self.hang_s < 0:
            raise ValueError(f"hang_s must be >= 0, got {self.hang_s}")
        if self.hang_s > 0 and self.stage != "transfer":
            raise ValueError("hang_s is only meaningful for the 'transfer' "
                             "stage (the watchdogged one)")


@dataclasses.dataclass(frozen=True)
class FailureRecord:
    """One quarantined scenario in a campaign's structured failure report:
    which scenario, which pipeline stage gave up on it, why, and after how
    many attempts. The scenario's ``CampaignResult`` metric row is NaN."""

    scenario: int
    stage: str
    reason: str
    attempts: int


class FaultPlan:
    """A deterministic, consumable schedule of injected faults.

    Construct explicitly from :class:`FaultSpec`\\ s plus a set of
    permanently NaN-poisoned scenario indices, or reproducibly via
    :meth:`random`. The campaign loop calls :meth:`fire` at each pipeline
    stage and :meth:`poison_mask` at each metric collection; ``log``
    records every injection as ``(stage, chunk, kind)`` so tests can
    assert exactly what fired.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (),
                 poison: Iterable[int] = ()):
        self.specs = tuple(specs)
        self.poison = frozenset(int(i) for i in poison)
        self.log: list[tuple[str, int, str]] = []
        self._remaining = [s.times for s in self.specs]
        self._lock = threading.Lock()

    @classmethod
    def random(cls, seed: int, n_chunks: int, n_scenarios: int,
               n_transient: int = 2, n_poison: int = 1,
               stages: Sequence[str] = ("transfer", "dispatch")
               ) -> "FaultPlan":
        """Seeded random plan: ``n_transient`` single-shot faults on random
        chunks/stages plus ``n_poison`` permanently poisoned scenarios —
        the same seed builds the same plan, so a failing fuzz case replays
        exactly."""
        rng = np.random.default_rng(seed)
        specs = [FaultSpec(stage=str(rng.choice(list(stages))),
                           chunk=int(rng.integers(max(n_chunks, 1))))
                 for _ in range(n_transient)]
        poison = (rng.choice(n_scenarios, size=min(n_poison, n_scenarios),
                             replace=False)
                  if n_poison > 0 else ())
        return cls(specs, poison)

    def fire(self, stage: str, chunk: int) -> None:
        """Consult the plan at a pipeline stage visit: consume and apply
        the first live matching spec (raise :class:`InjectedFault` /
        :class:`FaultAbort`, or sleep ``hang_s``); no-op otherwise."""
        hang = None
        with self._lock:
            for k, spec in enumerate(self.specs):
                if spec.stage != stage:
                    continue
                if spec.chunk is not None and spec.chunk != chunk:
                    continue
                if self._remaining[k] == 0:
                    continue
                if self._remaining[k] > 0:
                    self._remaining[k] -= 1
                self.log.append(
                    (stage, chunk, "hang" if spec.hang_s > 0 else "raise"))
                hang = spec.hang_s
                break
            else:
                return
        if stage == "abort":
            raise FaultAbort(f"injected abort at chunk {chunk}")
        if hang and hang > 0:
            time.sleep(hang)  # the watchdog, not this sleep, raises
            return
        raise InjectedFault(stage, chunk)

    def poison_mask(self, idxs: Sequence[int]) -> np.ndarray:
        """[len(idxs)] bool: which of these scenario rows to NaN-poison."""
        return np.asarray([int(i) in self.poison for i in idxs], bool)

    def n_fired(self, stage: str | None = None) -> int:
        with self._lock:
            return sum(1 for s, _, _ in self.log
                       if stage is None or s == stage)
