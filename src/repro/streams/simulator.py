"""Discrete-time fluid simulation of a distributed stream application over a
bandwidth-constrained fabric (reproduces the paper's testbed, §VI).

Each tick (``dt`` seconds):
  1. network transfer: every flow moves min(Q_s, x_f·dt) MB from its sender
     queue to its receiver queue — x is the policy's rate vector (TCP max-min,
     the paper's App-aware Alg. 1, App-Fair, or a fixed vector for the
     brute-force motivation study);
  2. processing: each instance consumes from its receiver queues — *join*
     instances advance in lock-step with their proportional inputs (a starved
     input stalls the join: the paper's core phenomenon), others consume
     work-conserving up to proc_rate;
  3. emission: consumed MB × selectivity is split over outgoing flows per the
     grouping weights; sources additionally generate gen_rate·dt.

The whole run is one `jax.lax.scan`, jitted; policies recompute rates inside
the scan (TCP every tick — idealized instant congestion control; App-aware
every Δt, matching the paper's 5 s controller interval).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import LinkProgram, allocate
from repro.core.flowstate import FlowState
from repro.core.multiapp import (
    ewma_throughput,
    group_by_throughput,
    strict_priority_alloc,
)
from repro.core.tcp import demand_limited_maxmin
from repro.net.topology import Topology
from repro.streams.app import InstanceGraph, source_sink_paths

_EPS = 1e-9
INTERNAL_RATE = 1e6  # MB/s: same-machine flows move at memory speed
_LAT_CAP = 1e4       # s: cap on per-flow latency contribution (stalled flows)


@functools.partial(
    jax.tree_util.register_dataclass,
    meta_fields=("tuples_per_mb", "n_apps"),
    data_fields=(
        "R", "caps", "kinds", "has_links", "M_in", "w_out", "p_in",
        "proc_rate", "selectivity", "gen_rate", "is_join", "is_sink",
        "join_dst", "droppable", "dst_of_flow", "paths", "app_of_flow",
        "app_of_inst",
    ),
)
@dataclasses.dataclass
class CompiledSim:
    """Structure of one simulation (pytree: arrays data, scalars static)."""

    # network
    R: Any               # [F, L]
    caps: Any            # [L]
    kinds: Any           # [L]
    has_links: Any       # [F] bool
    # dataflow
    M_in: Any            # [I, F] flow f ends at instance i
    w_out: Any           # [I, F] share of inst output onto flow
    p_in: Any            # [F] proportion of dst's input expected on flow
    proc_rate: Any       # [I]
    selectivity: Any     # [I]
    gen_rate: Any        # [I]
    is_join: Any         # [I] bool
    is_sink: Any         # [I] bool
    join_dst: Any        # [F] bool: flow terminates at a join instance
    droppable: Any       # [F] bool: stale excess is discarded at the join
    dst_of_flow: Any     # [F]
    paths: Any           # [P, F], rows pre-scaled by 1/P (Σ of path waits
                         #         = mean latency; zero rows are neutral)
    tuples_per_mb: float
    app_of_flow: Any     # [F] int
    app_of_inst: Any     # [I] int
    n_apps: int

    @property
    def program(self) -> LinkProgram:
        return LinkProgram(R=self.R, capacity=self.caps, kind=self.kinds)


def compile_sim(
    graph: InstanceGraph,
    topo: Topology,
    machine_of_inst: np.ndarray,
    app_of_inst: np.ndarray | None = None,
    n_apps: int = 1,
) -> CompiledSim:
    flows = graph.flow_pairs(machine_of_inst)
    R = topo.routing_matrix(flows)
    M_in = graph.in_matrix()
    # steady-state volumes -> expected input proportions per dst instance,
    # with semantic `join_share` overrides (paper's TI: the join consumes the
    # congestion stream at its *useful* rate, not its volume-average rate)
    from repro.streams.placement import _steady_state_flow_volume

    vol = _steady_state_flow_volume(graph) + 1e-12
    edges = graph.app.edges
    share = np.array(
        [edges[e].join_share if edges[e].join_share is not None else np.nan
         for e in graph.edge_of_flow]
    )
    p_in = np.zeros(graph.n_flows)
    for i in range(graph.n_instances):
        sel = graph.dst_of_flow == i
        if not sel.any():
            continue
        ov = sel & ~np.isnan(share)
        free = sel & np.isnan(share)
        # overridden edges: edge share split within the edge by volume
        used = 0.0
        for e in np.unique(graph.edge_of_flow[ov]):
            fe = ov & (graph.edge_of_flow == e)
            p_in[fe] = edges[e].join_share * vol[fe] / vol[fe].sum()
            used += edges[e].join_share
        if free.any():
            p_in[free] = max(1.0 - used, 0.0) * vol[free] / vol[free].sum()
        s = p_in[sel].sum()
        if s > 0:
            p_in[sel] /= s
    droppable = np.array([edges[e].droppable for e in graph.edge_of_flow])
    # pre-scale path masks by 1/P: the latency estimate becomes a plain sum,
    # which stays correct when `fleet.pad_sim` appends all-zero path rows
    paths = source_sink_paths(graph)
    paths = paths / max(paths.shape[0], 1)
    app_of_inst = (
        np.zeros(graph.n_instances, np.int32) if app_of_inst is None else app_of_inst
    )
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return CompiledSim(
        R=f32(R),
        caps=f32(topo.capacities),
        kinds=jnp.asarray(topo.link_kinds),
        has_links=jnp.asarray(R.sum(1) > 0),
        M_in=f32(M_in),
        w_out=f32(graph.w_out),
        p_in=f32(p_in),
        proc_rate=f32(np.minimum(graph.proc_rate, 1e9)),
        selectivity=f32(graph.selectivity),
        gen_rate=f32(graph.gen_rate),
        is_join=jnp.asarray(graph.is_join),
        is_sink=jnp.asarray(graph.is_sink),
        join_dst=jnp.asarray(graph.is_join[graph.dst_of_flow]),
        droppable=jnp.asarray(droppable),
        dst_of_flow=jnp.asarray(graph.dst_of_flow),
        paths=f32(paths),
        tuples_per_mb=float(graph.app.tuples_per_mb),
        app_of_flow=jnp.asarray(app_of_inst[graph.dst_of_flow], jnp.int32),
        app_of_inst=jnp.asarray(app_of_inst, jnp.int32),
        n_apps=int(n_apps),
    )


# --------------------------------------------------------------------------
# one simulation tick (shared by all policies)
# --------------------------------------------------------------------------
def _tick(sim: CompiledSim, Qs, Qr, x, dt, qcap):
    # receiver-window flow control: never overflow the receive buffer
    transfer = jnp.minimum(jnp.minimum(Qs, x * dt),
                           jnp.maximum(qcap - Qr, 0.0))
    Qs = Qs - transfer
    Qr = Qr + transfer

    # --- processing ---------------------------------------------------
    ratio = Qr / jnp.maximum(sim.p_in, _EPS)                     # [F]
    masked = jnp.where(sim.M_in > 0, ratio[None, :], jnp.inf)    # [I, F]
    join_amt = jnp.min(masked, axis=1)                           # [I]
    join_amt = jnp.where(jnp.isfinite(join_amt), join_amt, 0.0)
    join_amt = jnp.minimum(join_amt, sim.proc_rate * dt)
    consume_join = join_amt[sim.dst_of_flow] * sim.p_in          # [F]

    total_in = sim.M_in @ Qr                                     # [I]
    amt = jnp.minimum(total_in, sim.proc_rate * dt)
    frac = amt / jnp.maximum(total_in, _EPS)
    consume_any = Qr * frac[sim.dst_of_flow]

    consume = jnp.where(sim.join_dst, consume_join, consume_any)
    consume = jnp.minimum(consume, Qr)

    # sender-side backpressure (Storm's bounded send buffers): an instance
    # whose outgoing queue is full stalls its processing / generation
    in_i = sim.M_in @ consume                                    # [I]
    out_i = sim.selectivity * in_i + sim.gen_rate * dt
    prod = sim.w_out.T @ out_i                                   # [F]
    space = jnp.maximum(qcap - Qs, 0.0)
    scale_f = jnp.clip(space / jnp.maximum(prod, _EPS), 0.0, 1.0)
    # droppable (latest-value) streams never backpressure upstream: the app
    # overwrites stale records in its send queue instead of blocking
    stalled = jnp.where((sim.w_out > 0) & ~sim.droppable[None, :],
                        scale_f[None, :], jnp.inf)
    stall_i = jnp.min(stalled, axis=1)                           # [I]
    stall_i = jnp.where(jnp.isfinite(stall_i), stall_i, 1.0)

    consume = consume * stall_i[sim.dst_of_flow]
    Qr = Qr - consume
    # stale-data discard: droppable join inputs keep only a small working
    # window; bytes beyond it were carried by the network for nothing.
    Qr = jnp.where(sim.droppable, jnp.minimum(Qr, 0.5), Qr)
    in_i = sim.M_in @ consume
    out_i = sim.selectivity * in_i + sim.gen_rate * dt * stall_i
    Qs = Qs + sim.w_out.T @ out_i
    # latest-value send queues hold only the freshest working window
    Qs = jnp.where(sim.droppable, jnp.minimum(Qs, 0.5), Qs)

    sink_mb = jnp.sum(jnp.where(sim.is_sink, in_i, 0.0))
    sink_mb_app = jax.ops.segment_sum(
        jnp.where(sim.is_sink, in_i, 0.0), sim.app_of_inst, num_segments=sim.n_apps
    )
    drain = consume / dt                                         # [F] MB/s

    # --- latency estimate (per source→sink path) ----------------------
    wait = jnp.minimum(
        Qs / jnp.maximum(x, _EPS) + Qr / jnp.maximum(drain, _EPS), _LAT_CAP
    )
    path_lat = sim.paths @ wait                                  # [P]
    latency = jnp.sum(path_lat)  # rows carry 1/P => this is the path mean

    link_load = transfer @ sim.R / dt                            # [L] MB/s
    return Qs, Qr, transfer, drain, (sink_mb, sink_mb_app, latency, link_load)


# --------------------------------------------------------------------------
# policies
# --------------------------------------------------------------------------
def _tcp_rates(sim: CompiledSim, Qs, Qr, prod_rate, drain_ewma, dt, qcap):
    # sender-side demand, clamped by the receiver window (rwnd): a flow whose
    # receive buffer is full only demands its drain rate — real TCP frees the
    # bottleneck for other flows exactly this way.
    send = Qs / dt + prod_rate
    rwnd = jnp.maximum(qcap - Qr, 0.0) / dt + drain_ewma
    demand = jnp.minimum(send, rwnd)
    x = demand_limited_maxmin(sim.R, sim.caps, demand)
    return jnp.where(sim.has_links, jnp.minimum(x, demand), INTERNAL_RATE)


def _appaware_rates(sim: CompiledSim, state: FlowState, dt_alloc,
                    backfill_iters=8, solver: str = "sort"):
    x = allocate(sim.program, state, dt=dt_alloc,
                 backfill_iters=backfill_iters, solver=solver)
    return jnp.where(sim.has_links, x, INTERNAL_RATE)


@dataclasses.dataclass
class SimResult:
    sink_mb: np.ndarray        # [T]
    sink_mb_app: np.ndarray    # [T, A]
    latency: np.ndarray        # [T]
    link_load: np.ndarray      # [T, L]
    caps: np.ndarray           # [L]
    kinds: np.ndarray          # [L]
    tuples_per_mb: float
    dt: float

    def _warm(self, arr):
        return arr[arr.shape[0] // 4:]

    @property
    def throughput_tps(self) -> float:
        """App throughput: completed tuples/s at the sinks (post-warmup)."""
        return float(self._warm(self.sink_mb).mean() / self.dt * self.tuples_per_mb)

    @property
    def throughput_tps_per_app(self) -> np.ndarray:
        return np.asarray(
            self._warm(self.sink_mb_app).mean(0) / self.dt * self.tuples_per_mb
        )

    @property
    def avg_latency_s(self) -> float:
        return float(self._warm(self.latency).mean())

    def bottleneck_utilization(self, threshold: float = 0.5) -> float:
        """Avg utilization over bottlenecked links — links carrying ≥
        ``threshold`` of their capacity (paper Fig. 12 'average link
        throughput over all bottlenecked links')."""
        load = self._warm(self.link_load).mean(0)
        util = load / np.maximum(self.caps, _EPS)
        hot = util >= threshold
        if not hot.any():
            hot = util >= util.max() * 0.999
        return float(util[hot].mean())


@functools.partial(
    jax.jit,
    static_argnames=("policy", "n_ticks", "dt", "upd_every",
                     "alpha", "n_groups", "solver"),
)
def _run(sim: CompiledSim, policy: str, n_ticks: int, dt: float,
         upd_every: int, x_fixed=None, alpha: float = 0.5, n_groups: int = 8,
         qcap: float = 8.0, solver: str = "sort"):
    F = sim.R.shape[0]
    z = jnp.zeros((F,), jnp.float32)

    def policy_rates(Qs, Qr, B, prod_rate, drain_ewma, v_acc, ls, lr, mu):
        if policy == "tcp":
            return _tcp_rates(sim, Qs, Qr, prod_rate, drain_ewma, dt, qcap)
        if policy == "fixed":
            return jnp.where(sim.has_links, x_fixed, INTERNAL_RATE)
        if policy == "appaware":
            # the application profiler reports the *useful* receiver backlog
            # B (bytes transferred but not yet joined — stale drops still
            # count as backlog: the paper's memory-overrun signal, Fig. 5)
            st = FlowState(ls_t=ls, lr_t=lr, v=v_acc, ls_t1=Qs, lr_t1=B)
            return _appaware_rates(sim, st, dt * upd_every, solver=solver)
        if policy == "appfair":
            prio = group_by_throughput(mu, n_groups)
            x = strict_priority_alloc(
                sim.R, sim.caps, sim.app_of_flow, prio, n_groups=n_groups
            )
            return jnp.where(sim.has_links, x, INTERNAL_RATE)
        raise ValueError(policy)

    def body(carry, tick):
        (Qs, Qr, B, x, v_acc, ls, lr, prod_rate, drain_ewma, mu,
         mu_acc) = carry
        do_upd = (tick % upd_every) == 0

        def updated(_):
            mu_new = ewma_throughput(mu, mu_acc / (dt * upd_every), alpha)
            x_new = policy_rates(Qs, Qr, B, prod_rate, drain_ewma, v_acc,
                                 ls, lr, mu_new)
            return x_new, z, Qs, B, mu_new, jnp.zeros_like(mu_acc)

        def kept(_):
            return x, v_acc, ls, lr, mu, mu_acc

        x, v_acc, ls, lr, mu, mu_acc = jax.lax.cond(do_upd, updated, kept, None)

        Qs1, Qr1, transfer, drain, (sink, sink_app, lat, load) = _tick(
            sim, Qs, Qr, x, dt, qcap)
        prod_rate = (sim.w_out.T @ (sim.selectivity * (sim.M_in @ transfer)
                                    + sim.gen_rate * dt)) / dt
        drain_ewma = 0.5 * drain_ewma + 0.5 * drain
        B1 = jnp.clip(B + transfer - drain * dt, 0.0, 8.0 * qcap)
        return (
            (Qs1, Qr1, B1, x, v_acc + transfer, ls, lr, prod_rate,
             drain_ewma, mu, mu_acc + sink_app),
            (sink, sink_app, lat, load),
        )

    mu0 = jnp.zeros((sim.n_apps,), jnp.float32)
    carry0 = (z, z, z, z, z, z, z, z, z, mu0, mu0)
    _, ys = jax.lax.scan(body, carry0, jnp.arange(n_ticks))
    return ys


def smoke_seconds(seconds: float, cap: float = 120.0) -> float:
    """CI short-run mode: ``REPRO_SMOKE=1`` caps run length so the tier-1
    suite finishes in minutes on a CPU runner (same dt, same warmup logic)."""
    if os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0"):
        return min(seconds, cap)
    return seconds


def resolve_upd_every(policy: str, dt: float, upd_every: int | None) -> int:
    if upd_every is None:
        return int(round(5.0 / dt)) if policy in ("appaware", "appfair") else 1
    return upd_every


def simulate(
    sim: CompiledSim,
    policy: str = "tcp",
    seconds: float = 600.0,
    dt: float = 0.5,
    upd_every: int | None = None,
    x_fixed=None,
    alpha: float = 0.5,
    n_groups: int = 8,
    qcap: float = 8.0,
    solver: str = "sort",
) -> SimResult:
    """Run one experiment (paper §VI: 600 s runs, Δt = 5 s allocator)."""
    n_ticks = int(round(smoke_seconds(seconds) / dt))
    upd_every = resolve_upd_every(policy, dt, upd_every)
    sink, sink_app, lat, load = _run(
        sim, policy, n_ticks, dt, upd_every,
        x_fixed=None if x_fixed is None else jnp.asarray(x_fixed, jnp.float32),
        alpha=alpha, n_groups=n_groups, qcap=qcap, solver=solver,
    )
    return SimResult(
        sink_mb=np.asarray(sink),
        sink_mb_app=np.asarray(sink_app),
        latency=np.asarray(lat),
        link_load=np.asarray(load),
        caps=np.asarray(sim.caps),
        kinds=np.asarray(sim.kinds),
        tuples_per_mb=sim.tuples_per_mb,
        dt=dt,
    )
