"""Discrete-time fluid simulation of a distributed stream application over a
bandwidth-constrained fabric (reproduces the paper's testbed, §VI).

Each tick (``dt`` seconds):
  1. network transfer: every flow moves min(Q_s, x_f·dt) MB from its sender
     queue to its receiver queue — x is the policy's rate vector (TCP max-min,
     the paper's App-aware Alg. 1, App-Fair, or a fixed vector for the
     brute-force motivation study);
  2. processing: each instance consumes from its receiver queues — *join*
     instances advance in lock-step with their proportional inputs (a starved
     input stalls the join: the paper's core phenomenon), others consume
     work-conserving up to proc_rate;
  3. emission: consumed MB × selectivity is split over outgoing flows per the
     grouping weights; sources additionally generate gen_rate·dt.

The whole run is one `jax.lax.scan`, jitted; policies recompute rates inside
the scan (TCP every tick — idealized instant congestion control; App-aware
every Δt, matching the paper's 5 s controller interval).

**In-run network dynamics:** link capacity is a function of time. A
:class:`repro.net.topology.LinkSchedule` (sinusoidal diurnal components +
piecewise-constant failure/recovery events) compiles into per-sim arrays;
``_caps_over`` evaluates the whole ``[T, L]`` capacity trajectory once per
run and the scan consumes it as an ``xs`` stream, so the per-tick cost of a
schedule is one dynamic slice. Policies re-solve against ``caps(t_upd)`` at
their update ticks; between updates the *network itself* enforces the
current capacity (a failed link moves no bytes even while the controller's
rates are stale — that stale window is exactly the transient the paper's
Fig. 5/12 regime is about). A sim compiled without a schedule (S = 0
sinusoids, E = 0 events) skips every dynamic term *by shape* and runs the
static path unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import LinkProgram, allocate
from repro.core.flowstate import FlowState
from repro.core.multiapp import (
    ewma_throughput,
    group_by_throughput,
    strict_priority_alloc,
)
from repro.core.tcp import maxmin_fused_step, maxmin_order_init
from repro.net.topology import LinkSchedule, RouteSchedule, Topology
from repro.streams.app import InstanceGraph, source_sink_paths

_EPS = 1e-9
INTERNAL_RATE = 1e6  # MB/s: same-machine flows move at memory speed
_LAT_CAP = 1e4       # s: cap on per-flow latency contribution (stalled flows)

# The campaign summary vector computed by the in-program metric epilogue
# (`_metrics_epilogue`), in order. Throughput entries are MB-based (the
# per-scenario ``tuples_per_mb`` conversion is one exact scalar multiply,
# applied host-side by the consumers) so one padded fleet program serves
# scenarios with different tuple densities.
CAMPAIGN_METRICS = (
    "avg_tput_mb_s",      # post-warmup mean sink rate
    "final_tput_mb_s",    # smoothed sink rate at the last tick
    "avg_latency_s",      # post-warmup mean path latency
    "utilization",        # bottleneck-link utilization (Fig. 12 metric)
    "dip_depth",          # fractional dip after t_event (0 = none)
    "recovery_time_s",    # settling time after t_event (inf = never)
    "total_sink_mb",      # total MB delivered to sinks
)


def metric_index(name: str) -> int:
    return CAMPAIGN_METRICS.index(name)


@functools.partial(
    jax.tree_util.register_dataclass,
    meta_fields=("tuples_per_mb", "n_apps"),
    data_fields=(
        "R", "caps", "kinds", "has_links", "M_in", "w_out", "p_in",
        "proc_rate", "selectivity", "gen_rate", "is_join", "is_sink",
        "join_dst", "droppable", "dst_of_flow", "src_of_flow", "w_of_flow",
        "path_w", "app_of_flow", "app_of_inst",
        "sin_amp", "sin_omega", "sin_phase",
        "ev_t0", "ev_t1", "ev_link", "ev_scale",
        "route_bank", "route_t", "route_state",
    ),
)
@dataclasses.dataclass
class CompiledSim:
    """Structure of one simulation (pytree: arrays data, scalars static)."""

    # network
    R: Any               # [F, L]
    caps: Any            # [L] base capacities (schedule scales them in-run)
    kinds: Any           # [L]
    has_links: Any       # [F] bool
    # dataflow
    M_in: Any            # [I, F] flow f ends at instance i
    w_out: Any           # [I, F] share of inst output onto flow
    p_in: Any            # [F] proportion of dst's input expected on flow
    proc_rate: Any       # [I]
    selectivity: Any     # [I]
    gen_rate: Any        # [I]
    is_join: Any         # [I] bool
    is_sink: Any         # [I] bool
    join_dst: Any        # [F] bool: flow terminates at a join instance
    droppable: Any       # [F] bool: stale excess is discarded at the join
    dst_of_flow: Any     # [F]
    src_of_flow: Any     # [F]
    w_of_flow: Any       # [F] = w_out[src_of_flow[f], f] (the column's only
                         #      nonzero: each flow has one source instance)
    path_w: Any          # [F] per-flow latency weight = Σ_p paths[p, f]/P
                         #     (the path-mean contraction, pre-collapsed so
                         #     the scan never carries a [P, F] matvec; the
                         #     latency itself is a host-side dot — see
                         #     SimResult construction)
    tuples_per_mb: float
    app_of_flow: Any     # [F] int
    app_of_inst: Any     # [I] int
    n_apps: int
    # capacity schedule (see repro.net.topology.LinkSchedule); S = 0 / E = 0
    # means static caps and the simulator skips the dynamic terms by shape
    sin_amp: Any         # [S, L]
    sin_omega: Any       # [S, L]
    sin_phase: Any       # [S, L]
    ev_t0: Any           # [E]
    ev_t1: Any           # [E]
    ev_link: Any         # [E] int32
    ev_scale: Any        # [E]
    # mid-run rerouting bank (see repro.net.topology.RouteSchedule):
    # S_r = 0 means static routing and the simulator skips the per-tick
    # state stream and bank gather by shape. ``route_t``/``route_state``
    # share the S_r axis with the bank (S_r = max(states, intervals)):
    # padded interval slots never activate (t0 = inf) and padded bank
    # states are never indexed. Only R is banked: rerouting re-picks
    # *links*, never flow endpoints, so the per-flow fields derived from
    # the instance graph (src_of_flow / w_of_flow / path_w) and
    # ``has_links`` (dead routes are retained, not dropped) are
    # route-state-invariant.
    route_bank: Any      # [S_r, F, L] routing matrix per route state
    route_t: Any         # [S_r] interval start times (inf = padding)
    route_state: Any     # [S_r] int32 state index per interval

    @property
    def program(self) -> LinkProgram:
        return LinkProgram(R=self.R, capacity=self.caps, kind=self.kinds)

    def program_at(self, caps_t, R=None) -> LinkProgram:
        return LinkProgram(R=self.R if R is None else R,
                           capacity=caps_t, kind=self.kinds)

    @property
    def is_dynamic(self) -> bool:
        """Whether a capacity schedule is attached — a *shape* predicate
        (S > 0 sinusoids or E > 0 events), so it is trace-time static and
        every consumer (scan stream, enforcement, caps_t reporting) gates
        on the same definition."""
        return self.sin_amp.shape[0] > 0 or self.ev_t0.shape[0] > 0

    @property
    def is_rerouting(self) -> bool:
        """Whether a route bank is attached — the same kind of *shape*
        predicate as :attr:`is_dynamic`: S_r = 0 sims never stream a state
        index or gather from the bank, so static-routing runs are bitwise
        the pre-reroute path."""
        return self.route_bank.shape[0] > 0


def _validate_sim_inputs(where: str, *,
                         finite_nonneg: Sequence[tuple[str, Any]] = (),
                         nonneg_inf_ok: Sequence[tuple[str, Any]] = ()
                         ) -> None:
    """Reject poisoned scenario inputs at the compile boundary with an
    error naming the offending field, instead of letting a NaN flow
    silently through the whole scan and surface as a garbage metric row.

    Two classes, because +inf is *load-bearing* in this codebase:
    ``finite_nonneg`` fields (capacities, demands, event scales) must be
    finite and ≥ 0; ``nonneg_inf_ok`` fields may be +inf — event times
    use inf for "never" (schedule padding, permanent failures) and
    ``proc_rate`` uses inf for "unbounded" (clamped at compile) — but NaN
    and negative values are always poison."""
    for field, a in finite_nonneg:
        a = np.asarray(a, np.float64)
        bad = ~np.isfinite(a) | (a < 0)
        if bad.any():
            i = int(np.flatnonzero(bad.ravel())[0])
            raise ValueError(
                f"{where}: {field} must be finite and non-negative; got "
                f"{field}.ravel()[{i}] = {a.ravel()[i]}")
    for field, a in nonneg_inf_ok:
        a = np.asarray(a, np.float64)
        bad = np.isnan(a) | (a < 0)
        if bad.any():
            i = int(np.flatnonzero(bad.ravel())[0])
            raise ValueError(
                f"{where}: {field} must be non-negative and not NaN "
                f"(+inf is allowed); got "
                f"{field}.ravel()[{i}] = {a.ravel()[i]}")


def compile_sim(
    graph: InstanceGraph,
    topo: Topology,
    machine_of_inst: np.ndarray,
    app_of_inst: np.ndarray | None = None,
    n_apps: int = 1,
    schedule: LinkSchedule | None = None,
    reroute: "bool | RouteSchedule" = False,
) -> CompiledSim:
    """Compile one scenario. ``reroute=True`` derives a
    :class:`~repro.net.topology.RouteSchedule` from ``schedule``'s events
    (the SDN controller reprograms routes around failed links mid-run); an
    explicit ``RouteSchedule`` is used as-is. A schedule whose events never
    change the route set collapses to a single state and compiles exactly
    like ``reroute=False`` — the bank stays empty (S_r = 0) and the run is
    bitwise the static-routing path."""
    flows = graph.flow_pairs(machine_of_inst)
    R = topo.routing_matrix(flows)
    M_in = graph.in_matrix()
    # steady-state volumes -> expected input proportions per dst instance,
    # with semantic `join_share` overrides (paper's TI: the join consumes the
    # congestion stream at its *useful* rate, not its volume-average rate)
    from repro.streams.placement import _steady_state_flow_volume

    vol = _steady_state_flow_volume(graph) + 1e-12
    edges = graph.app.edges
    share = np.array(
        [edges[e].join_share if edges[e].join_share is not None else np.nan
         for e in graph.edge_of_flow]
    )
    p_in = np.zeros(graph.n_flows)
    for i in range(graph.n_instances):
        sel = graph.dst_of_flow == i
        if not sel.any():
            continue
        ov = sel & ~np.isnan(share)
        free = sel & np.isnan(share)
        # overridden edges: edge share split within the edge by volume
        used = 0.0
        for e in np.unique(graph.edge_of_flow[ov]):
            fe = ov & (graph.edge_of_flow == e)
            p_in[fe] = edges[e].join_share * vol[fe] / vol[fe].sum()
            used += edges[e].join_share
        if free.any():
            p_in[free] = max(1.0 - used, 0.0) * vol[free] / vol[free].sum()
        s = p_in[sel].sum()
        if s > 0:
            p_in[sel] /= s
    droppable = np.array([edges[e].droppable for e in graph.edge_of_flow])
    # collapse the [P, F] path masks to one per-flow weight vector: the
    # latency estimate is linear in the per-flow waits (Σ_p Σ_f paths[p, f]
    # · wait[f] / P), so the path axis contracts at compile time — the scan
    # outputs raw waits and the SimResult takes the dot on the host, which
    # keeps the estimate bitwise-independent of fleet padding (an XLA
    # matvec re-associates when the contraction length changes)
    paths = source_sink_paths(graph)
    path_w = paths.sum(0) / max(paths.shape[0], 1)
    app_of_inst = (
        np.zeros(graph.n_instances, np.int32) if app_of_inst is None else app_of_inst
    )
    if schedule is None:
        schedule = LinkSchedule.empty(topo.n_links)
    elif schedule.n_links != topo.n_links:
        raise ValueError(
            f"schedule covers {schedule.n_links} links, topology has "
            f"{topo.n_links}")
    ev_link = np.asarray(schedule.ev_link)
    if ev_link.size and (ev_link.min() < 0
                         or ev_link.max() >= topo.n_links):
        # a stale schedule (built for another topology) would otherwise be
        # silently clipped onto the wrong link by the jitted evaluation
        raise ValueError(
            f"schedule event links {ev_link} out of range for "
            f"{topo.n_links} links")
    _validate_sim_inputs(
        "compile_sim",
        finite_nonneg=[("capacities", topo.capacities),
                       ("gen_rate", graph.gen_rate),
                       ("ev_scale", schedule.ev_scale)],
        nonneg_inf_ok=[("proc_rate", graph.proc_rate),
                       ("ev_t0", schedule.ev_t0),
                       ("ev_t1", schedule.ev_t1)])
    F, L = len(flows), topo.n_links
    if reroute is True:
        reroute = RouteSchedule.from_events(topo, flows, schedule)
    if isinstance(reroute, RouteSchedule):
        if reroute.routes.shape[1:] != (F, L):
            raise ValueError(
                f"route schedule is [{reroute.routes.shape[1]} flows, "
                f"{reroute.routes.shape[2]} links]; scenario has "
                f"[{F}, {L}]")
        if reroute.n_states > 1:
            # single shared S_r axis for bank + interval arrays: padded
            # intervals never activate, padded bank states never indexed
            sr = max(reroute.n_states, reroute.n_intervals)
            route_bank = np.zeros((sr, F, L), np.float32)
            route_bank[:reroute.n_states] = reroute.routes
            route_t = np.full((sr,), np.inf, np.float32)
            route_t[:reroute.n_intervals] = reroute.t0
            route_state = np.zeros((sr,), np.int32)
            route_state[:reroute.n_intervals] = reroute.state
        else:
            # one reachable state == static routing: skip by shape
            route_bank = np.zeros((0, F, L), np.float32)
            route_t = np.zeros((0,), np.float32)
            route_state = np.zeros((0,), np.int32)
    else:
        route_bank = np.zeros((0, F, L), np.float32)
        route_t = np.zeros((0,), np.float32)
        route_state = np.zeros((0,), np.int32)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return CompiledSim(
        R=f32(R),
        caps=f32(topo.capacities),
        kinds=jnp.asarray(topo.link_kinds),
        has_links=jnp.asarray(R.sum(1) > 0),
        M_in=f32(M_in),
        w_out=f32(graph.w_out),
        p_in=f32(p_in),
        proc_rate=f32(np.minimum(graph.proc_rate, 1e9)),
        selectivity=f32(graph.selectivity),
        gen_rate=f32(graph.gen_rate),
        is_join=jnp.asarray(graph.is_join),
        is_sink=jnp.asarray(graph.is_sink),
        join_dst=jnp.asarray(graph.is_join[graph.dst_of_flow]),
        droppable=jnp.asarray(droppable),
        dst_of_flow=jnp.asarray(graph.dst_of_flow),
        src_of_flow=jnp.asarray(graph.src_of_flow),
        w_of_flow=f32(graph.w_out[graph.src_of_flow,
                                  np.arange(graph.n_flows)]),
        path_w=f32(path_w),
        tuples_per_mb=float(graph.app.tuples_per_mb),
        app_of_flow=jnp.asarray(app_of_inst[graph.dst_of_flow], jnp.int32),
        app_of_inst=jnp.asarray(app_of_inst, jnp.int32),
        n_apps=int(n_apps),
        sin_amp=f32(schedule.sin_amp),
        sin_omega=f32(schedule.sin_omega),
        sin_phase=f32(schedule.sin_phase),
        ev_t0=f32(schedule.ev_t0),
        ev_t1=f32(schedule.ev_t1),
        ev_link=jnp.asarray(schedule.ev_link, jnp.int32),
        ev_scale=f32(schedule.ev_scale),
        route_bank=f32(route_bank),
        route_t=f32(route_t),
        route_state=jnp.asarray(route_state, jnp.int32),
    )


def _route_states_over(sim: CompiledSim, ts: jnp.ndarray) -> jnp.ndarray:
    """Per-tick route-state index [T] — the routing analogue of
    ``_caps_over``: evaluated once per run outside the scan and streamed
    as ``xs``, so selecting the active route state costs one [F, L] gather
    per tick, never a recompile or a ``lax.cond``.

    Piecewise-constant lookup: tick t takes the last interval whose start
    time is ≤ t. Padded interval slots start at +inf (never counted) and
    all-padding rows (a static scenario packed into a rerouting bucket)
    clamp to interval 0, whose bank slot holds that scenario's base R.
    """
    j = jnp.sum(ts[:, None] >= sim.route_t[None, :], axis=1) - 1
    return sim.route_state[jnp.maximum(j, 0)]


def _caps_over(sim: CompiledSim, ts: jnp.ndarray) -> jnp.ndarray:
    """Evaluate the capacity schedule on a tick grid: [T, L].

    Computed once per run *outside* the scan and streamed in as ``xs`` — a
    schedule costs one dynamic slice per tick, not per-tick trig/scatter.
    Sims without sinusoids (S = 0) or events (E = 0) skip those terms by
    shape; a zero-amplitude / never-active schedule multiplies by exactly
    1.0, so the constant-schedule path is bitwise-identical to static caps.
    """
    L = sim.caps.shape[0]
    caps = jnp.broadcast_to(sim.caps[None, :], (ts.shape[0], L))
    if sim.sin_amp.shape[0]:
        wave = jnp.sum(
            sim.sin_amp[None] * jnp.sin(
                sim.sin_omega[None] * ts[:, None, None]
                + sim.sin_phase[None]), axis=1)           # [T, L]
        caps = caps * (1.0 + wave)
    if sim.ev_t0.shape[0]:
        active = (ts[:, None] >= sim.ev_t0[None]) & (
            ts[:, None] < sim.ev_t1[None])                # [T, E]
        mult = jnp.where(active, sim.ev_scale[None], 1.0)
        idx = jnp.clip(sim.ev_link, 0, L - 1)
        ones = jnp.ones((L,), caps.dtype)
        scale = jax.vmap(lambda m: ones.at[idx].multiply(m))(mult)
        caps = caps * scale
    return jnp.maximum(caps, 0.0)


def _metrics_epilogue(sink, wait, load, caps_grid, path_w, dt: float,
                      t_event: float, win_s: float = 5.0,
                      pre_s: float = 20.0, frac: float = 0.95,
                      hot_thresh: float = 0.5) -> jnp.ndarray:
    """On-device reduction of one run's trajectories to the
    :data:`CAMPAIGN_METRICS` vector — THE metric definition for both the
    streamed campaign path (where only this ``[n_metrics]`` summary ever
    leaves the device) and the materialized path (``simulate`` /
    ``FleetRunner.run`` attach the same in-program vector to
    ``SimResult.metrics``), so streamed and materialized metrics are one
    computation, not two reimplementations that can drift.

    Mirrors the host-side ``SimResult`` properties (``throughput_tps``,
    ``avg_latency_s``, ``bottleneck_utilization``, ``dip_depth``,
    ``recovery_time_s``) up to float re-association — the host properties
    stay the readable reference; a consistency test pins the two together.
    Runs under the fleet vmap on padded shapes: padded flows wait 0 s with
    zero ``path_w`` weight, padded links carry zero load against huge
    capacity, so padding never moves a metric.

    Known ULP-level sensitivity: ``sink.sum()`` (the ``total_sink_mb``
    entry) is the epilogue's only full-length un-normalized reduction, and
    XLA re-associates its reduction tree when the batch axis is
    SPMD-sharded (a 4-device ``run`` lowers a different tree for the
    per-device row count than the unsharded bucket). Trajectories and
    every other metric are bitwise under sharding; a regression test pins
    the drift to this one op at a couple of ULP
    (``tests/test_multidevice.py``). Not "fixed" by a
    sequential accumulator on purpose — that would change the unsharded
    value and break bitwise continuity of existing static-fleet results.
    """
    T = sink.shape[0]
    warm = T // 4
    rate = sink / dt                                           # [T] MB/s
    lat_t = wait @ path_w                                      # [T]
    # bottleneck utilization per SimResult.bottleneck_utilization: mean
    # per-tick utilization against the *scheduled* capacity, averaged over
    # links carrying >= hot_thresh of capacity (all-cold fallback: the
    # near-max links)
    util = (load[warm:] / jnp.maximum(caps_grid[warm:], _EPS)).mean(0)
    hot = util >= hot_thresh
    hot = jnp.where(hot.any(), hot, util >= util.max() * 0.999)
    utilization = (jnp.where(hot, util, 0.0).sum()
                   / jnp.maximum(hot.sum(), 1).astype(util.dtype))
    # transient metrics on the win_s-smoothed throughput (same edge
    # handling as SimResult._smooth_tput: divide by the actual sample
    # count so the trace boundaries don't fake a dip)
    w = max(int(round(win_s / dt)), 1)
    kern = jnp.ones((w,), rate.dtype)
    r = (jnp.convolve(rate, kern, mode="same")
         / jnp.convolve(jnp.ones_like(rate), kern, mode="same"))
    i = min(int(round(t_event / dt)), T - 1)                   # static
    pre_mean = r[max(0, i - int(round(pre_s / dt))):max(i, 1)].mean()
    post = r[i:]
    post_min = post.min()
    dip = jnp.where(pre_mean > _EPS,
                    jnp.maximum((pre_mean - post_min)
                                / jnp.maximum(pre_mean, _EPS), 0.0), 0.0)
    # settling time, branchless (the host version's dynamic slice
    # `inside[first_out:]` becomes a masked argmax over a static window)
    P = post.shape[0]
    if P < 2:
        recovery = jnp.zeros((), rate.dtype)
    else:
        steady = post[-max(P // 4, 1):].mean()
        inside = (post >= frac * steady) & (post * frac <= steady)
        first_out = jnp.argmax(~inside)
        cand = inside & (jnp.arange(P) >= first_out)
        recovery = jnp.where(
            inside.all(), 0.0,
            jnp.where(cand.any(), jnp.argmax(cand).astype(rate.dtype) * dt,
                      jnp.inf))
    return jnp.stack([
        rate[warm:].mean(),
        r[-1],
        lat_t[warm:].mean(),
        utilization,
        dip,
        recovery.astype(rate.dtype),
        sink.sum(),
    ])


# --------------------------------------------------------------------------
# one simulation tick (shared by all policies)
# --------------------------------------------------------------------------
def _tick(sim: CompiledSim, Qs, Qr, x, dt, qcap, caps_t=None, enforce=True,
          R_t=None):
    """One fluid step against the *current* link capacities ``caps_t``.

    Fused dispatch chain: ``M_in`` and ``w_out`` have exactly one nonzero
    per flow column (the flow's destination / source instance), so the
    back half of the original chain collapses algebraically —
    ``M_in @ (consume·stall[dst]) = (M_in @ consume)·stall`` and
    ``w_out.T @ v = v[src]·w_of_flow`` — replacing two of the per-tick
    [I, F] matmuls with O(F) gathers. The remaining contractions stay as
    matmuls / masked reductions on purpose: under the fleet engine's vmap
    they lower to batched GEMMs and reduces, where segment/scatter forms
    would serialize on CPU backends.

    ``enforce`` gates the per-tick capacity enforcement *per scenario*
    (a python bool for standalone sims, a traced scalar under the fleet
    vmap): a genuinely static scenario batched into a scheduled pack keeps
    its exact static semantics — ``transfer = desired · 1.0``, bitwise the
    static path — instead of taking the enforcement arm on bitwise-equal
    but re-rounded scaled loads. This is what lets brute-force ``x_fixed``
    studies (whose rate vectors are deliberately link-infeasible) share
    buckets with scheduled scenarios.

    ``R_t`` is the tick's active routing matrix when a route bank is
    attached (``None`` — the common case — reads ``sim.R``, leaving the
    static-routing trace untouched). Transfers load the links of the
    *current* routes: the SDN controller has already reprogrammed the
    switches, whatever the policy's stale rate vector was solved against.
    """
    R = sim.R if R_t is None else R_t
    dst, src = sim.dst_of_flow, sim.src_of_flow

    # receiver-window flow control: never overflow the receive buffer
    desired = jnp.minimum(jnp.minimum(Qs, x * dt),
                          jnp.maximum(qcap - Qr, 0.0))
    if caps_t is None or enforce is False:
        # static capacities: the policies' rate vectors are already
        # link-feasible, so the transfer needs no per-tick capacity check
        # (the pre-dynamics semantics — and cost — exactly)
        transfer = desired
    else:
        # the network enforces the *current* capacity: between controller
        # updates a failed/shrunk link moves at most caps_t·dt, whatever
        # the stale rate vector says. Feasible loads scale by exactly 1.0,
        # so a constant schedule reproduces the static path.
        load0 = desired @ R                                      # [L] MB
        lscale = jnp.where(load0 > caps_t * dt,
                           jnp.clip(caps_t * dt / jnp.maximum(load0, _EPS),
                                    0.0, 1.0),
                           1.0)
        fscale = jnp.min(jnp.where(R > 0, lscale[None, :], jnp.inf),
                         axis=1)
        fscale = jnp.where(jnp.isfinite(fscale), fscale, 1.0)
        if enforce is not True:
            # traced per-scenario gate: un-enforced rows multiply by
            # exactly 1.0, which is bitwise the static transfer
            fscale = jnp.where(enforce, fscale, 1.0)
        transfer = desired * fscale
    Qs = Qs - transfer
    Qr = Qr + transfer

    # --- processing ---------------------------------------------------
    ratio = Qr / jnp.maximum(sim.p_in, _EPS)                     # [F]
    masked = jnp.where(sim.M_in > 0, ratio[None, :], jnp.inf)    # [I, F]
    join_amt = jnp.min(masked, axis=1)                           # [I]
    join_amt = jnp.where(jnp.isfinite(join_amt), join_amt, 0.0)
    join_amt = jnp.minimum(join_amt, sim.proc_rate * dt)
    consume_join = join_amt[dst] * sim.p_in                      # [F]

    total_in = sim.M_in @ Qr                                     # [I]
    amt = jnp.minimum(total_in, sim.proc_rate * dt)
    frac = amt / jnp.maximum(total_in, _EPS)
    consume_any = Qr * frac[dst]

    consume = jnp.where(sim.join_dst, consume_join, consume_any)
    consume = jnp.minimum(consume, Qr)

    # sender-side backpressure (Storm's bounded send buffers): an instance
    # whose outgoing queue is full stalls its processing / generation
    in_i = sim.M_in @ consume                                    # [I]
    out_i = sim.selectivity * in_i + sim.gen_rate * dt
    prod = out_i[src] * sim.w_of_flow                            # [F]
    space = jnp.maximum(qcap - Qs, 0.0)
    scale_f = jnp.clip(space / jnp.maximum(prod, _EPS), 0.0, 1.0)
    # droppable (latest-value) streams never backpressure upstream: the app
    # overwrites stale records in its send queue instead of blocking
    stalled = jnp.where((sim.w_out > 0) & ~sim.droppable[None, :],
                        scale_f[None, :], jnp.inf)
    stall_i = jnp.min(stalled, axis=1)                           # [I]
    stall_i = jnp.where(jnp.isfinite(stall_i), stall_i, 1.0)

    consume = consume * stall_i[dst]
    Qr = Qr - consume
    # stale-data discard: droppable join inputs keep only a small working
    # window; bytes beyond it were carried by the network for nothing.
    Qr = jnp.where(sim.droppable, jnp.minimum(Qr, 0.5), Qr)
    in_i = in_i * stall_i        # = M_in @ (consume·stall[dst]), fused
    out_i = sim.selectivity * in_i + sim.gen_rate * dt * stall_i
    Qs = Qs + out_i[src] * sim.w_of_flow   # = w_out.T @ out_i, fused
    # latest-value send queues hold only the freshest working window
    Qs = jnp.where(sim.droppable, jnp.minimum(Qs, 0.5), Qs)

    sink_in = jnp.where(sim.is_sink, in_i, 0.0)
    sink_mb = jnp.sum(sink_in)
    if sim.n_apps == 1:
        # single-app sims (the common case): the per-app split IS the total
        sink_mb_app = sink_mb[None]
    else:
        # small one-hot contraction instead of a segment_sum: under the
        # fleet vmap this is a batched GEMM where a scatter would serialize
        onehot = (sim.app_of_inst[None, :]
                  == jnp.arange(sim.n_apps)[:, None]).astype(sink_in.dtype)
        sink_mb_app = onehot @ sink_in
    drain = consume / dt                                         # [F] MB/s

    # --- latency estimate (per source→sink path) ----------------------
    # raw per-flow waits only; the path-mean contraction (path_w · wait)
    # happens host-side on the true [F] slice, so the reported latency is
    # bitwise-identical however the fleet engine pads/packs the flow axis
    wait = jnp.minimum(
        Qs / jnp.maximum(x, _EPS) + Qr / jnp.maximum(drain, _EPS), _LAT_CAP
    )

    link_load = transfer @ R / dt                                # [L] MB/s
    return Qs, Qr, transfer, drain, (sink_mb, sink_mb_app, wait, link_load)


# --------------------------------------------------------------------------
# policies
# --------------------------------------------------------------------------
def _tcp_rates(sim: CompiledSim, R, caps_t, Qs, Qr, prod_rate, drain_ewma,
               dt, qcap, order_carry):
    # sender-side demand, clamped by the receiver window (rwnd): a flow whose
    # receive buffer is full only demands its drain rate — real TCP frees the
    # bottleneck for other flows exactly this way.
    send = Qs / dt + prod_rate
    rwnd = jnp.maximum(qcap - Qr, 0.0) / dt + drain_ewma
    demand = jnp.minimum(send, rwnd)
    # fused fixed-trip max-min (demand caps folded into the fill): no
    # lax.while_loop in the per-tick hot path, so the policy batches under
    # vmap/SPMD exactly like appaware's allocator does. The demand-order
    # operand rides the scan carry (``order_carry``): adjacent ticks rarely
    # reorder the demand vector, so the solver only rebuilds its rank
    # machinery on an actual order change — bitwise-identical output either
    # way (see repro.core.tcp.maxmin_fused_step).
    x, order_carry, rebuilt = maxmin_fused_step(
        R, caps_t, demand, order_carry)
    x = jnp.where(sim.has_links, jnp.minimum(x, demand), INTERNAL_RATE)
    return x, order_carry, rebuilt


def _appaware_rates(sim: CompiledSim, R, caps_t, state: FlowState, dt_alloc,
                    backfill_iters=8, solver: str = "sort"):
    x = allocate(sim.program_at(caps_t, R=R), state, dt=dt_alloc,
                 backfill_iters=backfill_iters, solver=solver)
    return jnp.where(sim.has_links, x, INTERNAL_RATE)


@dataclasses.dataclass
class SimResult:
    sink_mb: np.ndarray        # [T]
    sink_mb_app: np.ndarray    # [T, A]
    latency: np.ndarray        # [T]
    link_load: np.ndarray      # [T, L]
    caps: np.ndarray           # [L] base capacities
    kinds: np.ndarray          # [L]
    tuples_per_mb: float
    dt: float
    caps_t: np.ndarray | None = None   # [T, L] per-tick capacities
    # [T] bool — ticks on which the tcp solver's demand-order cache rebuilt
    # its rank operand (all-False for non-tcp policies); observability for
    # the order cache's hit rate, not a correctness input
    order_rebuilds: np.ndarray | None = None
    # [n_metrics] — the in-program CAMPAIGN_METRICS summary computed by the
    # on-device epilogue (`_metrics_epilogue`). MB-based (tuples_per_mb is
    # applied by consumers); the campaign streaming path returns exactly
    # this vector, so "materialized metrics" and "streamed metrics" are by
    # construction one definition
    metrics: np.ndarray | None = None

    def metric(self, name: str) -> float:
        """One entry of the in-program epilogue vector by name (see
        ``CAMPAIGN_METRICS``)."""
        if self.metrics is None:
            raise ValueError("run did not compute the metric epilogue")
        return float(self.metrics[metric_index(name)])

    @property
    def n_order_rebuilds(self) -> int:
        return 0 if self.order_rebuilds is None else int(
            np.sum(self.order_rebuilds))

    def _warm(self, arr):
        return arr[arr.shape[0] // 4:]

    @property
    def caps_grid(self) -> np.ndarray:
        """Per-tick capacities [T, L] (static caps broadcast if no
        schedule ran)."""
        if self.caps_t is not None:
            return self.caps_t
        return np.broadcast_to(self.caps[None, :], self.link_load.shape)

    @property
    def throughput_tps(self) -> float:
        """App throughput: completed tuples/s at the sinks (post-warmup)."""
        return float(self._warm(self.sink_mb).mean() / self.dt * self.tuples_per_mb)

    @property
    def throughput_tps_per_app(self) -> np.ndarray:
        return np.asarray(
            self._warm(self.sink_mb_app).mean(0) / self.dt * self.tuples_per_mb
        )

    @property
    def avg_latency_s(self) -> float:
        return float(self._warm(self.latency).mean())

    def bottleneck_utilization(self, threshold: float = 0.5) -> float:
        """Avg utilization over bottlenecked links — links carrying ≥
        ``threshold`` of their capacity (paper Fig. 12 'average link
        throughput over all bottlenecked links'). Utilization is per-tick
        against the *scheduled* capacity, so a failed link at 10% capacity
        carrying 10% load counts as fully utilized, not idle."""
        load = self._warm(self.link_load)
        caps = self._warm(self.caps_grid)
        util_t = load / np.maximum(caps, _EPS)            # [T', L]
        util = util_t.mean(0)
        hot = util >= threshold
        if not hot.any():
            hot = util >= util.max() * 0.999
        return float(util[hot].mean())

    # ---- transient response (in-run schedules) -----------------------
    def _smooth_tput(self, win_s: float = 5.0) -> np.ndarray:
        """Sink throughput [T] (tuples/s) smoothed over ``win_s`` so the
        per-tick granularity doesn't alias the transient metrics. Edge
        windows divide by the actual sample count (a plain ``mode="same"``
        convolution would average in implicit zeros and fake a dip at the
        trace boundaries)."""
        w = max(int(round(win_s / self.dt)), 1)
        rate = self.sink_mb / self.dt * self.tuples_per_mb
        kern = np.ones(w)
        num = np.convolve(rate, kern, mode="same")
        den = np.convolve(np.ones_like(rate), kern, mode="same")
        return num / den

    def dip_depth(self, t_event: float, pre_s: float = 20.0,
                  win_s: float = 5.0) -> float:
        """Fractional throughput dip after an event at ``t_event``: how far
        the post-event minimum falls below the pre-event mean (0 = no dip,
        1 = complete stall)."""
        r = self._smooth_tput(win_s)
        i = min(int(round(t_event / self.dt)), r.shape[0] - 1)
        pre = r[max(0, i - int(round(pre_s / self.dt))):max(i, 1)]
        pre_mean = float(pre.mean()) if pre.size else 0.0
        if pre_mean <= _EPS:
            return 0.0
        post_min = float(r[i:].min()) if r[i:].size else pre_mean
        return max(0.0, (pre_mean - post_min) / pre_mean)

    def recovery_time_s(self, t_event: float, frac: float = 0.95,
                        win_s: float = 5.0) -> float:
        """Settling time after an event at ``t_event``: how long the
        smoothed throughput takes to first re-enter the ±(1−``frac``) band
        around its post-event steady state (mean over the last quarter of
        the post-event window) *after having left it* — covering both a
        dip-and-recover transient and a monotone decay onto a degraded
        plateau. 0 if it never leaves the band (no transient); ``inf`` if
        it leaves and never settles."""
        r = self._smooth_tput(win_s)
        i = min(int(round(t_event / self.dt)), r.shape[0] - 1)
        post = r[i:]
        if post.size < 2:
            return 0.0
        steady = float(post[-max(post.size // 4, 1):].mean())
        inside = (post >= frac * steady) & (post * frac <= steady)
        if inside.all():
            return 0.0
        first_out = int(np.argmax(~inside))
        ok = inside[first_out:]
        if not ok.any():
            return float("inf")
        return float(first_out + int(np.argmax(ok))) * self.dt


@functools.partial(
    jax.jit,
    static_argnames=("policy", "n_ticks", "dt", "upd_every",
                     "alpha", "n_groups", "solver", "with_metrics",
                     "t_event"),
)
def _run(sim: CompiledSim, policy: str, n_ticks: int, dt: float,
         upd_every: int, x_fixed=None, alpha: float = 0.5, n_groups: int = 8,
         qcap: float = 8.0, solver: str = "sort", enforce=None,
         with_metrics: bool = False, t_event: float = 0.0):
    F = sim.R.shape[0]
    # per-scenario capacity-enforcement gate (see _tick): standalone sims
    # enforce whenever they carry a schedule; the fleet engine passes a
    # traced scalar so static scenarios packed into scheduled buckets keep
    # exact static semantics
    if enforce is None:
        enforce = True
    z = jnp.zeros((F,), jnp.float32)
    # shape-static gate: sims compiled without a schedule (S = 0, E = 0)
    # skip the capacity stream, the per-tick enforcement, and the [T, L]
    # trajectory output entirely — the static path costs what it did
    # before in-run dynamics existed
    dynamic = sim.is_dynamic
    rerouting = sim.is_rerouting
    if dynamic or rerouting:
        ts = jnp.arange(n_ticks, dtype=jnp.float32) * dt
    if dynamic:
        caps_sched = _caps_over(sim, ts)              # [T, L]
    else:
        caps_sched = jnp.zeros((0, sim.caps.shape[0]), jnp.float32)
    # per-tick route-state stream (S_r > 0 only): the scan gathers the
    # active state's routing matrix from the precompiled bank — mid-run
    # rerouting without recompilation or lax.cond
    states_seq = _route_states_over(sim, ts) if rerouting else None

    no_rebuild = jnp.zeros((), bool)

    def policy_rates(R_upd, caps_t, Qs, Qr, B, prod_rate, drain_ewma, v_acc,
                     ls, lr, mu, oc):
        """→ (rates, order_carry', rebuilt). Only tcp threads a real order
        carry; the rest pass ``oc`` through untouched (an empty tuple, so
        the scan carry stays policy-minimal — statically gated below)."""
        if policy == "tcp":
            return _tcp_rates(sim, R_upd, caps_t, Qs, Qr, prod_rate,
                              drain_ewma, dt, qcap, oc)
        if policy == "fixed":
            x = jnp.where(sim.has_links, x_fixed, INTERNAL_RATE)
        elif policy == "appaware":
            # the application profiler reports the *useful* receiver backlog
            # B (bytes transferred but not yet joined — stale drops still
            # count as backlog: the paper's memory-overrun signal, Fig. 5)
            st = FlowState(ls_t=ls, lr_t=lr, v=v_acc, ls_t1=Qs, lr_t1=B)
            x = _appaware_rates(sim, R_upd, caps_t, st, dt * upd_every,
                                solver=solver)
        elif policy == "appfair":
            prio = group_by_throughput(mu, n_groups)
            x = strict_priority_alloc(
                R_upd, caps_t, sim.app_of_flow, prio, n_groups=n_groups
            )
            x = jnp.where(sim.has_links, x, INTERNAL_RATE)
        else:
            raise ValueError(policy)
        return x, oc, no_rebuild

    def body(carry, xs):
        tick, caps_t, state_t = xs
        (Qs, Qr, B, x, v_acc, ls, lr, prod_rate, drain_ewma, mu,
         mu_acc, oc) = carry
        caps_upd = sim.caps if caps_t is None else caps_t
        # active routing matrix: one [F, L] bank gather per tick. The
        # policies re-solve against R(t_upd) at their update ticks, so
        # appaware/tcp shift traffic off failed links as soon as their
        # controller interval fires.
        R_t = None if state_t is None else sim.route_bank[state_t]
        R_upd = sim.R if R_t is None else R_t

        def updated(_):
            mu_new = (ewma_throughput(mu, mu_acc / (dt * upd_every), alpha)
                      if policy == "appfair" else mu)
            x_new, oc_new, reb = policy_rates(
                R_upd, caps_upd, Qs, Qr, B, prod_rate, drain_ewma,
                v_acc, ls, lr, mu_new, oc)
            return (x_new, z, Qs, B, mu_new, jnp.zeros_like(mu_acc),
                    oc_new, reb)

        def kept(_):
            return x, v_acc, ls, lr, mu, mu_acc, oc, no_rebuild

        if upd_every == 1:
            # every-tick policies (tcp/fixed defaults): no lax.cond in the
            # hot loop — the branch dispatch and its fusion barrier go away
            x, v_acc, ls, lr, mu, mu_acc, oc, reb = updated(None)
        else:
            do_upd = (tick % upd_every) == 0
            x, v_acc, ls, lr, mu, mu_acc, oc, reb = jax.lax.cond(
                do_upd, updated, kept, None)

        Qs1, Qr1, transfer, drain, (sink, sink_app, wait, load) = _tick(
            sim, Qs, Qr, x, dt, qcap, caps_t=caps_t, enforce=enforce,
            R_t=R_t)
        # per-policy carry pieces are gated *statically*: a policy that
        # never reads prod_rate/B/mu_acc doesn't pay their per-tick ops
        if policy == "tcp":
            t_in = sim.M_in @ transfer
            out_i = sim.selectivity * t_in + sim.gen_rate * dt
            prod_rate = out_i[sim.src_of_flow] * sim.w_of_flow / dt
            drain_ewma = 0.5 * drain_ewma + 0.5 * drain
        if policy == "appaware":
            B = jnp.clip(B + transfer - drain * dt, 0.0, 8.0 * qcap)
            v_acc = v_acc + transfer
        if policy == "appfair":
            mu_acc = mu_acc + sink_app
        return (
            (Qs1, Qr1, B, x, v_acc, ls, lr, prod_rate,
             drain_ewma, mu, mu_acc, oc),
            (sink, sink_app, wait, load, reb),
        )

    mu0 = jnp.zeros((sim.n_apps,), jnp.float32)
    # the demand-order cache only exists on the tcp path: other policies
    # carry an empty pytree, so their scan carries cost exactly what they
    # did before the order cache existed
    oc0 = maxmin_order_init(F) if policy == "tcp" else ()
    carry0 = (z, z, z, z, z, z, z, z, z, mu0, mu0, oc0)
    # None is an empty pytree leaf: static sims stream no capacity xs and
    # static-routing sims stream no state index
    xs = (jnp.arange(n_ticks), caps_sched if dynamic else None, states_seq)
    _, ys = jax.lax.scan(body, carry0, xs)
    if not with_metrics:
        return (*ys, caps_sched)
    # on-device metric epilogue: reduce the trajectories to the
    # CAMPAIGN_METRICS summary *inside the program*, so a streaming caller
    # can fetch [n_metrics] floats and leave the [T, ...] arrays on device
    sink, _sink_app, wait, load, _reb = ys
    caps_grid = (caps_sched if dynamic else
                 jnp.broadcast_to(sim.caps[None, :],
                                  (n_ticks, sim.caps.shape[0])))
    metrics = _metrics_epilogue(sink, wait, load, caps_grid, sim.path_w,
                                dt, t_event)
    return (*ys, caps_sched, metrics)


def result_from_padded_row(sim: CompiledSim, b: int, dt: float,
                           sink, sink_app, wait, load, rebuilds,
                           caps_sched, metrics) -> SimResult:
    """Slice row ``b`` of a padded bucket's (host-side) outputs back to
    ``sim``'s true shapes — the ONE definition of a scenario's
    :class:`SimResult`, shared by the materialized fleet path and the
    streaming campaign collector so they cannot drift apart."""
    F = sim.R.shape[0]
    L, A = sim.caps.shape[0], sim.n_apps
    return SimResult(
        sink_mb=sink[b],
        sink_mb_app=sink_app[b][:, :A],
        # path-mean latency on the true [F] slice: bitwise-independent of
        # bucket padding and pack structure
        latency=wait[b][:, :F] @ np.asarray(sim.path_w),
        link_load=load[b][:, :L],
        caps=np.asarray(sim.caps),
        kinds=np.asarray(sim.kinds),
        tuples_per_mb=sim.tuples_per_mb,
        dt=dt,
        caps_t=caps_sched[b][:, :L] if sim.is_dynamic else None,
        order_rebuilds=rebuilds[b],
        metrics=None if metrics is None else metrics[b],
    )


def smoke_seconds(seconds: float, cap: float = 120.0) -> float:
    """CI short-run mode: ``REPRO_SMOKE=1`` caps run length so the tier-1
    suite finishes in minutes on a CPU runner (same dt, same warmup logic)."""
    if os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0"):
        return min(seconds, cap)
    return seconds


def resolve_upd_every(policy: str, dt: float, upd_every: int | None) -> int:
    if upd_every is None:
        return int(round(5.0 / dt)) if policy in ("appaware", "appfair") else 1
    return upd_every


def simulate(
    sim: CompiledSim,
    policy: str = "tcp",
    seconds: float = 600.0,
    dt: float = 0.5,
    upd_every: int | None = None,
    x_fixed=None,
    alpha: float = 0.5,
    n_groups: int = 8,
    qcap: float = 8.0,
    solver: str = "sort",
    t_event: float = 0.0,
) -> SimResult:
    """Run one experiment (paper §VI: 600 s runs, Δt = 5 s allocator)."""
    n_ticks = int(round(smoke_seconds(seconds) / dt))
    upd_every = resolve_upd_every(policy, dt, upd_every)
    sink, sink_app, wait, load, rebuilds, caps_sched, metrics = _run(
        sim, policy, n_ticks, dt, upd_every,
        x_fixed=None if x_fixed is None else jnp.asarray(x_fixed, jnp.float32),
        alpha=alpha, n_groups=n_groups, qcap=qcap, solver=solver,
        with_metrics=True, t_event=float(t_event),
    )
    return SimResult(
        sink_mb=np.asarray(sink),
        sink_mb_app=np.asarray(sink_app),
        latency=np.asarray(wait) @ np.asarray(sim.path_w),
        link_load=np.asarray(load),
        caps=np.asarray(sim.caps),
        kinds=np.asarray(sim.kinds),
        tuples_per_mb=sim.tuples_per_mb,
        dt=dt,
        caps_t=np.asarray(caps_sched) if sim.is_dynamic else None,
        order_rebuilds=np.asarray(rebuilds),
        metrics=np.asarray(metrics),
    )
