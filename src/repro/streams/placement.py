"""Instance placement strategies (paper §II-A.4).

A placement maps operator instances to machines; it fixes the communication
pattern (which flows are internal vs external, and which links they share).
The paper's motivation (Fig. 3) shows that placement alone is insufficient —
bandwidth allocation matters for *every* placement.
"""
from __future__ import annotations

import numpy as np

from repro.streams.app import InstanceGraph


def round_robin(graph: InstanceGraph, n_machines: int) -> np.ndarray:
    """Storm's default EvenScheduler-like assignment."""
    return np.arange(graph.n_instances) % n_machines


def packed(graph: InstanceGraph, n_machines: int) -> np.ndarray:
    """Fill machines one by one (minimizes machines used, maximizes
    co-location — and uplink contention)."""
    per = -(-graph.n_instances // n_machines)
    return np.arange(graph.n_instances) // per


def random_placement(graph: InstanceGraph, n_machines: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_machines, graph.n_instances)


def traffic_aware(graph: InstanceGraph, n_machines: int,
                  cap_per_machine: int | None = None) -> np.ndarray:
    """Greedy T-Storm-like heuristic [11]: repeatedly co-locate the endpoints
    of the heaviest flow, subject to a per-machine instance cap. Minimizes
    external traffic; the paper argues this is orthogonal to (and still
    needs) bandwidth allocation.

    The cap binds on *every* placement: each fallback picks the
    least-loaded machine **under cap** (a bare ``argmin(load)`` silently
    exceeded a user-supplied ``cap_per_machine`` once every machine it
    preferred was full). An infeasible cap (``cap · n_machines <
    n_instances``) raises instead of over-packing quietly.
    """
    I = graph.n_instances
    cap = -(-I // n_machines) if cap_per_machine is None else cap_per_machine
    if cap * n_machines < I:
        raise ValueError(
            f"cap_per_machine={cap} cannot place {I} instances on "
            f"{n_machines} machines")
    # estimated flow volumes: propagate generation through selectivities
    vol = _steady_state_flow_volume(graph)
    order = np.argsort(-vol, kind="stable")
    machine = -np.ones(I, dtype=np.int64)
    load = np.zeros(n_machines, dtype=np.int64)

    def place(i: int, m: int):
        machine[i] = m
        load[m] += 1

    def least_loaded_under_cap() -> int:
        open_m = np.flatnonzero(load < cap)
        return int(open_m[np.argmin(load[open_m])])

    for f in order:
        s, d = int(graph.src_of_flow[f]), int(graph.dst_of_flow[f])
        ms, md = machine[s], machine[d]
        if ms < 0 and md < 0:
            m = least_loaded_under_cap()
            place(s, m)
            if load[m] < cap:
                place(d, m)
            else:
                place(d, least_loaded_under_cap())
        elif ms < 0:
            place(s, md if load[md] < cap else least_loaded_under_cap())
        elif md < 0:
            place(d, ms if load[ms] < cap else least_loaded_under_cap())
    for i in range(I):
        if machine[i] < 0:
            place(i, least_loaded_under_cap())
    return machine


def _steady_state_flow_volume(graph: InstanceGraph, iters: int = 32) -> np.ndarray:
    """Fixed point of out = (gen + selectivity·in)·W_out ignoring capacity —
    the open-loop steady-state MB/s per flow."""
    I, F = graph.w_out.shape
    M_in = graph.in_matrix()
    inflow = np.zeros(I)
    for _ in range(iters):
        out = graph.gen_rate + graph.selectivity * inflow
        flow = graph.w_out.T @ out
        inflow = M_in @ flow
    return graph.w_out.T @ (graph.gen_rate + graph.selectivity * inflow)


STRATEGIES = {
    "round_robin": round_robin,
    "packed": packed,
    "random": random_placement,
    "traffic_aware": traffic_aware,
}
