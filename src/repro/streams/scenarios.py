"""Scenario generators for fleet simulation (`repro.streams.fleet`).

A :class:`Scenario` bundles everything `compile_sim` needs — app DAG,
topology, placement — under a name, so a study is "build a list of
scenarios, `compile` them, hand them to `simulate_many`". Generators cover
the axes the paper varies by hand (§VI) plus the robustness axes it leaves
open:

  * ``capacity_sweep``        — the paper's 10/15/20 Mbps grid × workloads
                                × single-/multi-hop bottlenecks (Figs. 8-9);
  * ``random_app``            — randomized layered DAGs (fan-out, joins,
                                key skew) for property-style robustness;
  * ``link_failure_sweep``    — seed workloads with a random subset of
                                links degraded to a fraction of capacity.
                                With ``in_run=True`` the failure happens
                                *mid-run* (and recovers) via a
                                :class:`~repro.net.topology.LinkSchedule`,
                                exercising the controller's transient
                                response; the static form stays as the
                                steady-state parity oracle;
  * ``time_varying_sweep``    — a sinusoidal (diurnal-style) capacity
                                cycle. Static form: one scenario per phase
                                (the batch axis explores time, each phase
                                quasi-static). ``in_run=True``: the cycle
                                runs *inside* each scenario as a schedule;
  * ``seed_fleet``            — a mixed ≥16-scenario fleet of all of the
                                above (including in-run schedules), the
                                default benchmark/test corpus;
  * ``campaign_fleet``        — N-scenario streaming-campaign corpus
                                (``FleetRunner.run_campaign``): the paper's
                                capacity grid × {static, in-run failure,
                                diurnal} × a seeded jitter axis, tiled to
                                exactly N scenarios over only 6 distinct
                                padded shapes so an arbitrarily large
                                campaign still compiles a handful of
                                executables.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.net.topology import (
    Link,
    LinkKind,
    LinkSchedule,
    Topology,
    big_switch,
    diurnal_schedule,
    fat_tree,
    link_failure_schedule,
)
from repro.streams.app import Edge, Grouping, InstanceGraph, Operator, StreamApp, parallelize
from repro.streams.placement import round_robin
from repro.streams.simulator import CompiledSim, compile_sim
from repro.streams.workloads import (
    PAPER_CAPS_MBPS,
    trending_topics,
    trucking_iot,
)


@dataclasses.dataclass
class Scenario:
    """One fully-specified simulation setup (pre-compilation)."""

    name: str
    graph: InstanceGraph
    topo: Topology
    placement: np.ndarray
    schedule: LinkSchedule | None = None   # in-run capacity dynamics
    reroute: bool = False                  # SDN rerouting around failures

    def compile(self) -> CompiledSim:
        return compile_sim(self.graph, self.topo, self.placement,
                           schedule=self.schedule, reroute=self.reroute)


def compile_fleet(scenarios: list[Scenario]) -> list[CompiledSim]:
    return [s.compile() for s in scenarios]


# ---------------------------------------------------------------- topology
def degrade_links(topo: Topology, link_ids: np.ndarray,
                  factor: float) -> Topology:
    """Copy of ``topo`` with the given links' capacity scaled by ``factor``
    (0 < factor ≤ 1): a soft link failure / brown-out."""
    hit = set(int(i) for i in link_ids)
    links = [
        Link(l.name, l.kind, l.capacity * (factor if i in hit else 1.0))
        for i, l in enumerate(topo.links)
    ]
    return dataclasses.replace(topo, links=links)


# ------------------------------------------------------------ random DAGs
def random_app(seed: int, max_depth: int = 4, max_parallelism: int = 3,
               name: str | None = None) -> StreamApp:
    """A random layered stream DAG: source → chain of operators with random
    parallelism / selectivity / joins / groupings → sink. Matches the shape
    distribution of the paper's apps (Fig. 7) without their tuning."""
    rng = np.random.default_rng(seed)
    depth = int(rng.integers(1, max_depth + 1))
    ops = [Operator("src", int(rng.integers(1, max_parallelism + 1)),
                    gen_rate=float(rng.uniform(0.5, 3.0)), proc_rate=100.0)]
    edges = []
    prev = "src"
    for k in range(depth):
        nm = f"op{k}"
        ops.append(Operator(
            nm, int(rng.integers(1, max_parallelism + 1)), proc_rate=100.0,
            selectivity=float(rng.uniform(0.3, 1.5)),
            join=bool(rng.integers(0, 2)),
        ))
        edges.append(Edge(
            prev, nm,
            rng.choice([Grouping.SHUFFLE, Grouping.KEY, Grouping.GLOBAL]),
            key_skew=float(rng.uniform(0.0, 1.0)),
        ))
        prev = nm
    ops.append(Operator("sink", 1, proc_rate=100.0, selectivity=0.0))
    edges.append(Edge(prev, "sink", Grouping.GLOBAL))
    return StreamApp(name or f"rand{seed}", ops, edges, tuples_per_mb=1000.0)


def random_scenarios(n: int, seed: int = 0, n_machines: int = 8,
                     cap_range: tuple[float, float] = (0.75, 3.0)
                     ) -> list[Scenario]:
    rng = np.random.default_rng(seed)
    out = []
    for k in range(n):
        app_seed = int(rng.integers(0, 2**31 - 1))
        g = parallelize(random_app(app_seed), seed=app_seed)
        topo = big_switch(n_machines, float(rng.uniform(*cap_range)))
        out.append(Scenario(f"rand{k}", g, topo,
                            round_robin(g, n_machines)))
    return out


# ------------------------------------------------------- paper-grid sweeps
_SEED_APPS = {"TT": trending_topics, "TI": trucking_iot}


def capacity_sweep(caps: dict[str, float] = PAPER_CAPS_MBPS,
                   multihop: bool = False, n_machines: int = 8,
                   seed: int = 0) -> list[Scenario]:
    """The paper's §VI grid: {TT, TI} × {10, 15, 20 Mbps}, single-hop
    (up/downlink bottleneck) or multi-hop (throttled fat-tree internals)."""
    out = []
    for app_name, mk in _SEED_APPS.items():
        g = parallelize(mk(), seed=seed)
        for cap_name, cap in caps.items():
            if multihop:
                topo = fat_tree(up=12.5).set_capacity(LinkKind.INTERNAL, cap)
            else:
                topo = big_switch(n_machines, cap)
            hop = "multihop" if multihop else "singlehop"
            out.append(Scenario(
                f"{app_name}_{cap_name}_{hop}", g, topo,
                round_robin(g, topo.n_machines)))
    return out


def link_failure_sweep(n: int = 6, seed: int = 0, fail_frac: float = 0.25,
                       degrade: float = 0.1, cap: float = 1.875,
                       in_run: bool = False, t_fail: float = 60.0,
                       t_recover: float = 90.0,
                       reroute: bool = False) -> list[Scenario]:
    """Seed workloads on a fat-tree with a random ``fail_frac`` of links
    degraded to ``degrade``× capacity — does the allocator route value
    (not just bytes) around brown-outs?

    ``in_run=False``: the degradation holds for the whole run (the original
    steady-state form — kept as the parity oracle for the scheduled path).
    ``in_run=True``: links fail at ``t_fail`` and recover at ``t_recover``
    *inside* the run, so the result traces the controller's transient
    (dip depth / recovery time, the paper's Fig. 5/12 regime).
    ``reroute=True`` (implies ``in_run``): the SDN controller additionally
    *reroutes* around the failure via a precompiled route bank
    (:class:`~repro.net.topology.RouteSchedule`); failures are drawn from
    the internal links only, so a surviving alternate core path exists —
    the regime where rerouting (not just re-allocating) pays."""
    rng = np.random.default_rng(seed)
    out = []
    for k in range(n):
        app_name = ("TT", "TI")[k % 2]
        g = parallelize(_SEED_APPS[app_name](), seed=seed)
        topo = fat_tree(up=12.5).set_capacity(LinkKind.INTERNAL, cap)
        if reroute:
            internal = np.flatnonzero(topo.link_kinds == int(LinkKind.INTERNAL))
            n_fail = max(1, int(fail_frac * internal.size))
            failed = rng.choice(internal, size=n_fail, replace=False)
            sched = link_failure_schedule(topo, failed, t_fail, t_recover,
                                          degrade)
            out.append(Scenario(
                f"{app_name}_failreroute{k}", g, topo,
                round_robin(g, topo.n_machines), schedule=sched,
                reroute=True))
            continue
        n_fail = max(1, int(fail_frac * topo.n_links))
        failed = rng.choice(topo.n_links, size=n_fail, replace=False)
        if in_run:
            sched = link_failure_schedule(topo, failed, t_fail, t_recover,
                                          degrade)
            out.append(Scenario(
                f"{app_name}_failrun{k}", g, topo,
                round_robin(g, topo.n_machines), schedule=sched))
        else:
            out.append(Scenario(
                f"{app_name}_fail{k}", g, degrade_links(topo, failed, degrade),
                round_robin(g, topo.n_machines)))
    return out


def time_varying_sweep(n_phases: int = 8, base_cap: float = 1.875,
                       amplitude: float = 0.4, app: str = "TT",
                       seed: int = 0, in_run: bool = False,
                       period_s: float = 120.0) -> list[Scenario]:
    """A diurnal-style capacity cycle.

    ``in_run=False``: sampled at ``n_phases`` points — link capacity =
    base·(1 + amplitude·sin(2π·phase/n_phases)), one scenario per phase;
    the batch axis *is* the time axis (each phase is long against the 5 s
    controller interval, so quasi-static). Kept as the steady-state oracle.
    ``in_run=True``: the cycle runs *inside* each scenario (period
    ``period_s``, one scenario per starting phase), so the controller
    tracks a genuinely moving capacity."""
    g = parallelize(_SEED_APPS[app](), seed=seed)
    out = []
    for p in range(n_phases):
        if in_run:
            topo = big_switch(8, base_cap)
            sched = diurnal_schedule(topo, period_s, amplitude,
                                     phase=2 * np.pi * p / n_phases)
            out.append(Scenario(f"{app}_cyclerun{p}", g, topo,
                                round_robin(g, 8), schedule=sched))
        else:
            cap = base_cap * (1.0 + amplitude
                              * np.sin(2 * np.pi * p / n_phases))
            topo = big_switch(8, float(cap))
            out.append(Scenario(f"{app}_phase{p}", g, topo,
                                round_robin(g, 8)))
    return out


def seed_fleet(seed: int = 0) -> list[Scenario]:
    """The default ≥16-scenario corpus: paper grid (single- and multi-hop),
    link failures (steady-state *and* in-run), capacity cycles (sampled
    *and* in-run), and random DAGs."""
    return (
        capacity_sweep(multihop=False, seed=seed)            # 6
        + capacity_sweep(multihop=True, seed=seed)           # 6
        + link_failure_sweep(n=4, seed=seed)                 # 4
        + time_varying_sweep(n_phases=4, seed=seed)          # 4
        + random_scenarios(4, seed=seed)                     # 4
        + link_failure_sweep(n=2, seed=seed, in_run=True)    # 2
        + time_varying_sweep(n_phases=2, seed=seed,
                             in_run=True)                    # 2
    )


def campaign_fleet(n: int, seed: int = 0, n_machines: int = 8,
                   n_fail: int = 2) -> list[Scenario]:
    """Parameterized campaign corpus for the streaming runtime: tile
    {TT, TI} × the paper's capacity grid × {static, in-run link failure,
    in-run diurnal cycle} to exactly ``n`` scenarios, with a seeded rng
    jittering the per-scenario knobs (failed links and failure window,
    cycle phase/period/amplitude) so every scenario is distinct.

    The tiling deliberately spans only 6 distinct padded shapes (2 app
    graphs × {no schedule, ``n_fail``-event schedule, 1-sinusoid
    schedule}), so however large ``n`` grows the bucket plan and the
    per-bucket compiled executables stay fixed — the property
    ``FleetRunner.run_campaign`` exploits to stream 10³–10⁴ scenarios
    through a handful of XLA programs.
    """
    rng = np.random.default_rng(seed)
    caps = list(PAPER_CAPS_MBPS.values())
    out = []
    for k in range(n):
        app_name = ("TT", "TI")[k % 2]
        g = parallelize(_SEED_APPS[app_name](), seed=seed)
        cap = caps[(k // 2) % len(caps)]
        kind = ("static", "fail", "diurnal")[(k // (2 * len(caps))) % 3]
        topo = big_switch(n_machines, cap)
        if kind == "fail":
            failed = rng.choice(topo.n_links, size=n_fail, replace=False)
            t_fail = float(rng.uniform(50.0, 70.0))
            sched = link_failure_schedule(
                topo, failed, t_fail,
                t_fail + float(rng.uniform(20.0, 40.0)),
                float(rng.uniform(0.05, 0.3)))
        elif kind == "diurnal":
            sched = diurnal_schedule(
                topo, period_s=float(rng.uniform(80.0, 160.0)),
                amplitude=float(rng.uniform(0.2, 0.5)),
                phase=float(rng.uniform(0.0, 2.0 * np.pi)))
        else:
            sched = None
        out.append(Scenario(f"{app_name}_{kind}{k}", g, topo,
                            round_robin(g, n_machines), schedule=sched))
    return out


def bench_fleet(seed: int = 0, n_random: int = 16) -> list[Scenario]:
    """The canonical 44-scenario benchmark corpus: :func:`seed_fleet` plus
    ``n_random`` extra random DAGs (fixed generator seed 42, matching the
    historical ``benchmarks/fleet.py`` setup). This is the corpus the
    ``BENCH_fleet.json`` numbers, the CI perf gate, and the
    packed-vs-per-bucket bitwise parity suite all run on — one definition,
    so a bench regression and a parity failure point at the same fleet."""
    return seed_fleet(seed=seed) + random_scenarios(n_random, seed=42)
