"""Batched multi-scenario simulation: run a *fleet* of independent
simulations as ONE fused, jitted executable per run behind a persistent
:class:`FleetRunner`.

The paper validates Alg. 1 on one 10-workstation topology (§VI); every
follow-up question — capacity sweeps, placement studies, link failures,
random-DAG robustness — is "run the same simulator on N variants". Doing
that as a python loop costs N separate XLA compilations (every scenario has
its own [F, L, I] shape) plus N dispatch streams. Padding everything to the
*global* max shape fixes the compile count but inflates the solver GEMMs
(the max-min fill is O(F²·L): padding a 9-flow scenario to 17 flows × 32
links costs ~7× its true solve). The runner splits the difference:

  1. **Overhead-aware shape bucketing** — scenarios are grouped into at
     most ``max_buckets`` buckets by greedy agglomerative merging under a
     *two-term* cost model (:func:`_flop_cost` + ``tick_overhead``):
     starting from one bucket per distinct true shape, merging a pair
     trades the padded-FLOP waste it adds against the fixed per-bucket
     per-tick overhead it removes (every bucket contributes one more set
     of scan-iteration ops per tick). ``max_buckets`` is a *cap*, not the
     operative knob: cheap-tick fleets (the "fixed" policy, tiny shapes)
     collapse to one bucket because overhead dominates, while
     solver-heavy fleets (tcp re-solves an O(F²L) max-min every tick)
     keep tighter buckets because padded FLOPs dominate. The FLOP model
     is policy-aware (tcp re-solves every tick; appaware pays its
     allocator per controller interval; scheduled shapes add the
     enforcement machinery; "fixed" pays the base tick only).
  2. **Single-dispatch packed execution** — all buckets of a plan run
     inside ONE jitted executable per (pack signature, policy, solver,
     n_ticks, …) key: each bucket keeps its own padded shape (no
     global-cover FLOP inflation) as its own vmap-over-scan inside the one
     XLA program, and a warm fleet run is exactly one kernel dispatch
     however many buckets the plan holds. Per-bucket results are
     bitwise-identical to dispatching each bucket as its own executable
     (``fused=False`` keeps that mode as the parity oracle); a fused
     single *scan* over all buckets was measured slower on CPU and
     non-bitwise (XLA cross-fuses the bucket bodies), so each bucket
     keeps its own scan.
  3. **Compile caching** — executables are cached per runner instance
     (``FleetRunner.compile_cache_size`` exposes occupancy for
     no-recompile assertions; two runners can never poison each other's
     counts). Bucket batch rows are rounded up to a small capacity quantum
     (:func:`_round_rows`), so a fleet that grows only in scenario count
     within the padded capacity reuses the executable without recompiling.
  4. **Staging buffers** — per (bucket shape, members, rows) the runner
     keeps preallocated numpy buffers; repeat calls re-stack scenarios by
     slice assignment into the existing buffers instead of re-padding
     every leaf through fresh allocations. Spare capacity rows simply keep
     their pad values: they are *inert scenarios* (zero generation/demand,
     huge-capacity INTERNAL links, never-active events) whose rows are
     dropped on return.
  5. **Device-resident packs** — each staged bucket is pushed to the
     device(s) once (pre-placed under the scenario-axis sharding) and the
     same arrays are re-passed on every warm call, so the steady state
     transfers nothing and converts nothing per call (~10² numpy→device
     conversions otherwise, milliseconds against a tens-of-ms run).
     Earlier revisions donated the input buffers instead; donation and
     input reuse are mutually exclusive, and on the fleet's small packs
     the saved H2D/conversion work beats the saved output allocation.

Padding within a bucket is *neutral by construction*: padded flows have no
routing-matrix entries, no producers, and zero queues, so they move no
bytes; padded links carry huge capacity and INTERNAL kind, so no solver
ever binds on them; padded instances generate/consume nothing; padded
capacity-schedule components are exact no-ops (zero-amplitude sinusoids,
never-active events), so fleets mixing scheduled and static scenarios
batch together without recompiling. A static scenario padded into a
*scheduled* bucket keeps its exact static semantics through the
per-scenario enforcement mask threaded into ``_tick`` (an un-enforced row
multiplies its transfer by exactly 1.0 — bitwise the static path), which
is also what lets brute-force ``x_fixed`` studies with deliberately
link-infeasible rate vectors share buckets with scheduled scenarios.

Exact parity with per-scenario ``simulate`` holds for every policy,
**including "appfair"**: its priority grouping depends on the number of
apps, so the runner buckets appfair fleets by *exact* ``n_apps`` (buckets
already group by shape; the app axis is simply never padded across
scenarios that disagree on app count) — heterogeneous-app fleets still run
as one dispatch, since every bucket lives in the same executable.

Beyond one-shot fleets, :meth:`FleetRunner.run_campaign` is the **streaming
campaign dispatch mode** for 10³–10⁴-scenario studies: the scenario list is
partitioned into fixed-shape chunks (the bucket plan is computed over the
*whole* campaign, then each bucket's members are chunked at a fixed padded
row count, so every chunk of a bucket reuses ONE compiled executable —
inert-spare quantization makes the ragged last chunk a no-recompile) and
streamed through a **three-stage pipeline**: (1) host *pack* into
triple-buffered preallocated numpy slots, (2) *H2D transfer* by a
dedicated worker thread (``jax.device_put`` onto the stream's device), and
(3) *compute* via async dispatch — so chunk *k+1*'s bytes are already
device-resident when chunk *k*'s dispatch returns, and the pack of *k+2*
overlaps both. Three slot phases, one per stage, because ``device_put`` on
CPU zero-copy aliases 64-byte-aligned host buffers: a slot may only be
refilled once its occupant's *execution* has been collected, and the
pipeline lags staging by at most two chunks. With more than one local
device (``--xla_force_host_platform_device_count`` on CPU, or a real
accelerator mesh) the chunk stream is **sharded along the scenario axis**:
chunk *j* runs on stream *j mod n_streams*, each stream owning its own
slots/worker-queue entry, and only the on-device metric epilogue's
``[rows, n_metrics]`` summary ever crosses the device boundary — full
``[B, T, …]`` trajectories are neither transferred nor retained unless the
caller opts in (``retain_trajectories=True``). Chunk row quantization is
device-count-independent, so campaign metrics are bitwise-identical at
every device count. ``chunk_rows="auto"`` sizes chunks from a measured
per-backend calibration of dispatch/sync overhead (see
:func:`calibrate_backend`; recorded in ``last_stats["calibration"]``).
Host staging memory is bounded by the three buffer slots per stream of
the active chunk shape (``last_stats["peak_staged_rows"]`` ≤ 3 × chunk
rows × streams) and device residency by the ≤ 2 in-flight chunks per
stream, independent of campaign size.

``pad_sim`` / ``stack_sims`` remain as the one-shot stacking primitives;
``simulate_many`` is a thin wrapper over a module-level runner, so the PR 1
API is unchanged.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import weakref
import zlib
from concurrent.futures import CancelledError as FuturesCancelledError
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeoutError
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import (
    Mesh,
    NamedSharding,
    PartitionSpec,
    SingleDeviceSharding,
)

from repro.core.tcp import maxmin_fused
from repro.net.topology import LinkKind
from repro.streams.faults import (
    FailureRecord,
    FaultPlan,
    InjectedFault,
)
from repro.streams.simulator import (
    CAMPAIGN_METRICS,
    CompiledSim,
    SimResult,
    _run,
    _validate_sim_inputs,
    metric_index,
    resolve_upd_every,
    result_from_padded_row,
    smoke_seconds,
)

# padded links must never constrain any solver: effectively infinite pipes
_PAD_CAP = 1e9

# Fallback per-bucket per-tick overhead, in the same proxy-FLOP units as
# `_flop_cost`: every bucket adds one more set of scan-iteration ops
# (dispatch of each fused kernel, loop bookkeeping) per tick, independent
# of how many scenarios ride in it. Hand-calibrated once against the
# `fleet_dispatch_floor` row of `benchmarks/fleet.py` on the 2-core CI
# container (≈4 µs per extra bucket-tick against solver GEMMs sustaining
# ≈3.7 GFLOP/s ⇒ ≈15k padded FLOPs per bucket-tick). The default path now
# *measures* both quantities at runtime (see `calibrate_backend`); this
# constant remains the `REPRO_CALIBRATE=0` escape hatch and the anchor of
# the CPU clamp band below.
TICK_OVERHEAD_FLOPS_CPU = 15e3

# Plan-stability clamp for the measured tick overhead, per backend. The
# planner invariants the test suite pins (fixed-policy fleets collapse to
# fewer buckets than tcp fleets; a lone infeasible static scenario merges
# into a scheduled bucket) were verified to hold across this whole band on
# the seed corpus, so a noisy measurement on a loaded container can shift
# *where* inside the band we land but never flip a plan-structure
# invariant. Unknown (wide) backends get a far looser band: per-op
# overhead there is genuinely orders of magnitude larger relative to a
# single scenario's FLOPs.
_CALIB_CLAMP = {"cpu": (8e3, 64e3)}
_CALIB_CLAMP_DEFAULT = (5e2, 1e6)


@dataclasses.dataclass(frozen=True)
class BackendCalibration:
    """Runtime-measured per-backend overhead model (see
    :func:`calibrate_backend`). All µs figures are medians of warm
    roundtrips; ``proxy_mflops`` is the *effective* rate at which this
    backend retires the proxy FLOPs of `_flop_cost`'s dominant solver
    term — measured on the real fused max-min fill, not a peak-GEMM
    probe, so overheads trade against FLOPs in the units the planner
    actually spends."""

    backend: str
    dispatch_us: float       # tiny jitted program: enqueue -> host result
    sync_us: float           # [64, n_metrics] device->host fetch roundtrip
    tick_overhead_us: float  # marginal cost of one extra scan iteration
    proxy_mflops: float      # effective proxy-FLOP rate of the solver probe
    tick_overhead_flops: float  # tick_overhead_us × rate, clamped
    clamped: bool            # True when the raw product left the band
    measured: bool           # False for the REPRO_CALIBRATE=0 fallback

    @property
    def chunk_overhead_s(self) -> float:
        """Fixed cost floor of one streaming-campaign chunk: one program
        dispatch plus one ``[rows, n_metrics]`` metric fetch."""
        return (self.dispatch_us + self.sync_us) * 1e-6


_CALIBRATION: dict[str, BackendCalibration] = {}


def _measure_calibration(backend: str) -> BackendCalibration:
    # (a) tiny-dispatch roundtrip: enqueue one trivial jitted program and
    # block — the per-chunk dispatch floor of the campaign loop
    f = jax.jit(lambda x: x * 2.0 + 1.0)
    x = jnp.arange(64, dtype=jnp.float32)
    jax.block_until_ready(f(x))

    def med_us(fn, reps=7):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)

    dispatch_us = med_us(lambda: jax.block_until_ready(f(x)))
    # (b) device->host fetch of a campaign-sized metric summary
    g = jax.jit(lambda m: m + 1.0)
    m = jnp.zeros((64, len(CAMPAIGN_METRICS)), jnp.float32)
    np.asarray(g(m))
    sync_us = med_us(lambda: np.asarray(g(m)))
    # (c) per-tick scan overhead by scan-length differencing. The body
    # must be *representative*, not trivial: XLA compiles an empty body to
    # nearly nothing, under-reporting the bookkeeping a real tick pays, so
    # this one runs a fused-kernel-scale handful of elementwise ops on a
    # small carry (compute itself cancels in the difference).
    carry0 = jnp.ones((32, 16), jnp.float32)

    def body(c, _):
        c = c * 0.999 + 0.001
        c = c + 0.1 * jnp.tanh(c)
        c = jnp.minimum(c * 1.001, 8.0)
        c = c - 0.05 * jnp.maximum(c - 1.0, 0.0)
        return c, ()

    def scan_of(n):
        fn = jax.jit(lambda c: jax.lax.scan(body, c, None, length=n)[0])
        jax.block_until_ready(fn(carry0))
        return med_us(lambda: jax.block_until_ready(fn(carry0)), reps=5)

    n_short, n_long = 32, 512
    tick_us = max((scan_of(n_long) - scan_of(n_short)) / (n_long - n_short),
                  0.05)
    # (d) effective proxy-FLOP rate: a vmapped fused max-min fill at seed-
    # corpus scale, credited with exactly the proxy FLOPs `_flop_cost`
    # bills a tcp solve of that shape — so rate × time is in planner units
    F, L, B = 17, 32, 32
    rng = np.random.default_rng(0)
    R = (rng.random((B, F, L)) < 0.2).astype(np.float32)
    caps = np.full((B, L), 100.0, np.float32)
    d = rng.uniform(1.0, 8.0, (B, F)).astype(np.float32)
    solve = jax.jit(jax.vmap(lambda r, c, dd: maxmin_fused(r, c, dd)))
    jax.block_until_ready(solve(R, caps, d))
    t_solve_us = med_us(lambda: jax.block_until_ready(solve(R, caps, d)),
                        reps=5)
    proxy_flops = B * 3.0 * 2.0 * (F + 1.0) * F * 2.0 * L
    proxy_mflops = proxy_flops / max(t_solve_us, 1e-3)
    lo, hi = _CALIB_CLAMP.get(backend, _CALIB_CLAMP_DEFAULT)
    raw = tick_us * proxy_mflops
    return BackendCalibration(
        backend=backend, dispatch_us=dispatch_us, sync_us=sync_us,
        tick_overhead_us=tick_us, proxy_mflops=proxy_mflops,
        tick_overhead_flops=float(min(max(raw, lo), hi)),
        clamped=not (lo <= raw <= hi), measured=True)


def calibrate_backend(force: bool = False) -> BackendCalibration:
    """Per-backend runtime overhead calibration, measured once per process
    (cached; ``force=True`` re-measures). Replaces the hardcoded
    ``TICK_OVERHEAD_FLOPS_CPU`` / 2e3 planner guess: the planner's
    overhead constant and the campaign's ``chunk_rows="auto"`` sizing both
    come from these probes, so the same code self-tunes on CPU today and
    on a wide backend later. ``REPRO_CALIBRATE=0`` skips the probes and
    returns the documented fallback constants."""
    backend = jax.default_backend()
    cached = _CALIBRATION.get(backend)
    if cached is not None and not force:
        return cached
    if os.environ.get("REPRO_CALIBRATE", "").strip() == "0":
        calib = BackendCalibration(
            backend=backend, dispatch_us=10.0, sync_us=20.0,
            tick_overhead_us=4.0, proxy_mflops=3700.0,
            tick_overhead_flops=(TICK_OVERHEAD_FLOPS_CPU
                                 if backend == "cpu" else 2e3),
            clamped=False, measured=False)
    else:
        calib = _measure_calibration(backend)
    _CALIBRATION[backend] = calib
    return calib


def _default_tick_overhead() -> float:
    return calibrate_backend().tick_overhead_flops


@dataclasses.dataclass(frozen=True)
class FleetShape:
    """Common padded shape of a stacked fleet (or of one bucket)."""

    n_flows: int
    n_links: int
    n_insts: int
    n_apps: int
    # capacity-schedule axes: sinusoidal components / failure events.
    # Padded sinusoids have zero amplitude, padded events never activate,
    # so static and scheduled scenarios batch together exactly.
    n_sins: int = 0
    n_events: int = 0
    # route-bank axis (S_r): 0 = static routing. A static-routing scenario
    # padded into a rerouting bucket gets its base R staged into bank slot
    # 0 with never-activating intervals, so its per-tick gather returns
    # exactly its static routing matrix.
    n_route_states: int = 0

    @classmethod
    def cover(cls, sims: Sequence[CompiledSim]) -> "FleetShape":
        """Smallest shape covering every sim in the fleet."""
        return cls(
            n_flows=max(s.R.shape[0] for s in sims),
            n_links=max(s.R.shape[1] for s in sims),
            n_insts=max(s.M_in.shape[0] for s in sims),
            n_apps=max(s.n_apps for s in sims),
            n_sins=max(s.sin_amp.shape[0] for s in sims),
            n_events=max(s.ev_t0.shape[0] for s in sims),
            n_route_states=max(s.route_bank.shape[0] for s in sims),
        )

    def merge(self, other: "FleetShape") -> "FleetShape":
        return FleetShape(*(max(a, b) for a, b in
                            zip(dataclasses.astuple(self),
                                dataclasses.astuple(other))))


def _sim_shape(sim: CompiledSim) -> FleetShape:
    return FleetShape(
        n_flows=sim.R.shape[0], n_links=sim.R.shape[1],
        n_insts=sim.M_in.shape[0], n_apps=sim.n_apps,
        n_sins=sim.sin_amp.shape[0], n_events=sim.ev_t0.shape[0],
        n_route_states=sim.route_bank.shape[0])


def _sim_content_sig(sim: CompiledSim) -> int:
    """crc32 over every staged field's bytes: the content half of the
    staging-reuse fingerprint. Object identity (the other half) cannot see
    in-place mutation of a scenario's arrays between warm calls; the byte
    hash can, at corpus scale in ~µs per scenario."""
    h = 0
    for field in _FIELD_SPECS:
        a = np.ascontiguousarray(np.asarray(getattr(sim, field)))
        h = zlib.crc32(a.tobytes(), h)
    return h


def _flop_cost(shape: FleetShape, policy: str = "tcp") -> float:
    """Per-tick per-scenario padded-FLOP proxy.

    The base term covers the simulator's [I, F] dataflow matmuls and
    [F, L] link products; the policy term covers the allocation solve
    inside the scan:

    * tcp / appfair — the fused max-min fill: (FILL_ROUNDS + 1)
      ``[F+1, F] @ [F, 2L]`` rank-prefix GEMMs against the order-only
      operand dominate at O(F²·L); tcp re-solves every tick
      (``upd_every == 1``), which is why tcp fleets are the most
      padding-sensitive. (Numerically identical to the pre-order-cache
      stacked ``[2F+2, F] @ [F, L]`` weight — 2·(F+1)·2L = 2·(2F+2)·L —
      so plans and bucket shapes are unchanged across that refactor.)
    * appaware — the allocator's sort-based fused solve plus 8 backfill
      sweeps per controller interval. The update gate's predicate is
      shared across the batch (the tick index is an unbatched scan
      stream), so the ``lax.cond`` stays a real branch under vmap and the
      per-tick cost amortizes over ``upd_every`` — the weight here is the
      *empirical* padding sensitivity (interleaved A/B showed merged
      covers hurting appaware nearly as much as tcp: its solve is
      memory-traffic- rather than GEMM-bound), not a derived op count.
    * fixed — no solve at all.

    Constants only matter *relative* to ``tick_overhead`` (same units), so
    the proxy needs the right scaling in F and L, not exact op counts.
    """
    F, L, I = shape.n_flows, shape.n_links, shape.n_insts
    base = F * L + 2.0 * I * F + 6.0 * F
    if shape.n_sins > 0 or shape.n_events > 0:
        # in-run schedule machinery: the [T, L] capacity stream plus the
        # per-tick transfer enforcement (load matmul, per-flow min over
        # links). Merging a static scenario into a scheduled bucket makes
        # it pay this — measured ~1.5× the base tick on the seed corpus —
        # so the planner only mixes static and scheduled shapes when
        # overhead genuinely dominates.
        base += 3.0 * F * L + 8.0 * L + 4.0 * shape.n_sins * L \
            + 4.0 * shape.n_events
    if shape.n_route_states > 0:
        # mid-run rerouting: the per-tick [F, L] bank gather plus the
        # interval lookup. Static scenarios merged into a rerouting bucket
        # pay this too (their base R rides bank slot 0), so the planner
        # weighs the mix like it does the schedule machinery.
        base += 2.0 * F * L + 4.0 * shape.n_route_states
    if policy in ("tcp", "appfair"):
        base += 3.0 * 2.0 * (F + 1.0) * F * 2.0 * L
    elif policy == "appaware":
        base += 40.0 * F * L
    return base


def _plan_buckets(sims: Sequence[CompiledSim], max_buckets: int,
                  exact_apps: bool = False, policy: str = "tcp",
                  tick_overhead: float = 0.0) -> list[tuple[list[int],
                                                            FleetShape]]:
    """Greedy agglomerative bucketing: start from one bucket per distinct
    true shape, repeatedly apply the cheapest merge. A merge is *forced*
    while the bucket count exceeds ``max_buckets`` and otherwise taken
    only when profitable — when the padded-FLOP waste it adds stays below
    the fixed per-bucket per-tick cost it removes (``tick_overhead``, same
    proxy-FLOP units as :func:`_flop_cost`), so cheap-tick fleets collapse
    toward one bucket while solver-heavy fleets keep tighter buckets and
    ``max_buckets`` acts as a cap rather than the operative knob. With
    ``exact_apps`` (the "appfair" policy) only buckets with equal
    ``n_apps`` may merge — the priority grouping is a function of the app
    count, so the app axis is never padded across disagreeing scenarios
    (the bucket count may then exceed the budget by necessity: one bucket
    per app count at minimum)."""
    by_shape: dict[tuple, list[int]] = {}
    for i, s in enumerate(sims):
        by_shape.setdefault(dataclasses.astuple(_sim_shape(s)), []).append(i)
    buckets = [(idxs, FleetShape(*key)) for key, idxs in by_shape.items()]

    def merge_waste(a, b):
        (ia, sa), (ib, sb) = a, b
        cover = sa.merge(sb)
        return ((len(ia) + len(ib)) * _flop_cost(cover, policy)
                - len(ia) * _flop_cost(sa, policy)
                - len(ib) * _flop_cost(sb, policy))

    while len(buckets) > 1:
        best = None
        for j in range(len(buckets)):
            for k in range(j + 1, len(buckets)):
                if exact_apps and (buckets[j][1].n_apps
                                   != buckets[k][1].n_apps):
                    continue
                w = merge_waste(buckets[j], buckets[k])
                if best is None or w < best[0]:
                    best = (w, j, k)
        if best is None:  # no feasible merge (exact_apps partitions)
            break
        if len(buckets) <= max_buckets and best[0] >= tick_overhead:
            break  # within budget and no merge pays for itself
        _, j, k = best
        (ij, sj), (ik, sk) = buckets[j], buckets[k]
        merged = (ij + ik, sj.merge(sk))
        buckets = [b for i, b in enumerate(buckets) if i not in (j, k)]
        buckets.append(merged)
    return buckets


def _round_rows(n: int, n_dev: int) -> int:
    """Padded batch-row capacity for a bucket of ``n`` scenarios: rounded
    up to the device count (so the scenario axis always shards evenly) and,
    for fleets large enough that a few inert rows are noise (≥ 16), to a
    small quantum — growth headroom, so a fleet that only gains scenarios
    within the padded capacity reuses its compiled executable."""
    n = -(-n // max(n_dev, 1)) * max(n_dev, 1)
    if n >= 16:
        q = 4 * max(n_dev, 1) // math.gcd(4, max(n_dev, 1))
        n = -(-n // q) * q
    return n


# chunk_rows="auto" bounds: the floor keeps chunks at the staging quantum
# (below it the balanced-chunk splitter and `_round_rows` would fight over
# ragged tails for no overhead win), the ceiling bounds peak staged memory
# at 2 slots × 256 rows per stream whatever the calibration says
AUTO_CHUNK_MIN = 16
AUTO_CHUNK_MAX = 256
AUTO_CHUNK_OVERHEAD_FRAC = 0.02


def _auto_chunk_rows(shape: FleetShape, policy: str, n_ticks: int,
                     calib: BackendCalibration) -> int:
    """Per-bucket chunk sizing from the backend calibration: the smallest
    row count that keeps the fixed per-chunk cost floor (one dispatch plus
    one metric fetch, `chunk_overhead_s`) under ``AUTO_CHUNK_OVERHEAD_FRAC``
    of the chunk's modeled compute. On CPU a scenario-trajectory is
    milliseconds of solve, so this lands at the floor (small chunks, small
    staging); on a wide backend per-row time collapses and the same formula
    grows chunks until dispatch overhead is amortized."""
    per_row_s = (_flop_cost(shape, policy) * n_ticks
                 / (calib.proxy_mflops * 1e6))
    rows = math.ceil(calib.chunk_overhead_s
                     / (AUTO_CHUNK_OVERHEAD_FRAC * max(per_row_s, 1e-12)))
    return int(min(max(rows, AUTO_CHUNK_MIN), AUTO_CHUNK_MAX))


# padding/stacking run in numpy: hundreds of tiny jnp.pad dispatches would
# dominate the batched path's wall-clock before XLA ever runs
def _pad1(a, n, value=0.0):
    a = np.asarray(a)
    pad = n - a.shape[0]
    return a if pad <= 0 else np.pad(a, (0, pad), constant_values=value)


def _pad2(a, n0, n1):
    a = np.asarray(a)
    p0, p1 = n0 - a.shape[0], n1 - a.shape[1]
    if p0 <= 0 and p1 <= 0:
        return a
    return np.pad(a, ((0, max(p0, 0)), (0, max(p1, 0))))


def _pad_route_fields(sim: CompiledSim, F: int, L: int,
                      SR: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad the route-bank family to ``SR`` states.

    A static-routing sim (S_r = 0) entering a rerouting shape stages its
    base R into bank slot 0 with all intervals at t = +inf: the per-tick
    state lookup clamps to interval 0 → state 0 → exactly the static
    routing matrix, so the gathered values equal ``sim.R`` on every tick.
    A rerouting sim pads with never-selected zero states / inert
    intervals.
    """
    sr0 = sim.route_bank.shape[0]
    bank = np.zeros((SR, F, L), np.float32)
    t = np.full((SR,), np.inf, np.float32)
    state = np.zeros((SR,), np.int32)
    if sr0 == 0:
        if SR > 0:
            bank[0] = _pad2(np.asarray(sim.R, np.float32), F, L)
    else:
        b = np.asarray(sim.route_bank, np.float32)
        bank[:sr0, :b.shape[1], :b.shape[2]] = b
        t[:sr0] = np.asarray(sim.route_t, np.float32)
        state[:sr0] = np.asarray(sim.route_state, np.int32)
    return bank, t, state


def pad_sim(sim: CompiledSim, shape: FleetShape,
            tuples_per_mb: float | None = None) -> CompiledSim:
    """Zero-pad ``sim`` to ``shape`` without changing its dynamics.

    ``tuples_per_mb`` (a *static* pytree field) may be overridden so every
    member of a fleet shares one treedef; callers keep the true value per
    scenario (``FleetRunner`` does) for throughput conversion.
    """
    F, L = shape.n_flows, shape.n_links
    I, A = shape.n_insts, shape.n_apps
    S, E = shape.n_sins, shape.n_events
    if sim.n_apps > A:
        raise ValueError(f"cannot pad n_apps {sim.n_apps} down to {A}")
    # the compile boundary already validates, but sims are mutable and may
    # be hand-built — catch poisoned fields before they pad into a fleet
    _validate_sim_inputs(
        "pad_sim",
        finite_nonneg=[("caps", sim.caps),
                       ("gen_rate", sim.gen_rate),
                       ("ev_scale", sim.ev_scale)],
        nonneg_inf_ok=[("proc_rate", sim.proc_rate),
                       ("ev_t0", sim.ev_t0),
                       ("ev_t1", sim.ev_t1)])
    f = False
    route_bank, route_t, route_state = _pad_route_fields(
        sim, F, L, shape.n_route_states)
    return CompiledSim(
        R=_pad2(sim.R, F, L),
        caps=_pad1(sim.caps, L, _PAD_CAP),
        kinds=_pad1(sim.kinds, L, int(LinkKind.INTERNAL)),
        has_links=_pad1(sim.has_links, F, f),
        M_in=_pad2(sim.M_in, I, F),
        w_out=_pad2(sim.w_out, I, F),
        p_in=_pad1(sim.p_in, F),
        proc_rate=_pad1(sim.proc_rate, I),
        selectivity=_pad1(sim.selectivity, I),
        gen_rate=_pad1(sim.gen_rate, I),
        is_join=_pad1(sim.is_join, I, f),
        is_sink=_pad1(sim.is_sink, I, f),
        join_dst=_pad1(sim.join_dst, F, f),
        droppable=_pad1(sim.droppable, F, f),
        dst_of_flow=_pad1(sim.dst_of_flow, F, 0),
        src_of_flow=_pad1(sim.src_of_flow, F, 0),
        w_of_flow=_pad1(sim.w_of_flow, F),
        path_w=_pad1(sim.path_w, F),
        tuples_per_mb=(sim.tuples_per_mb if tuples_per_mb is None
                       else float(tuples_per_mb)),
        app_of_flow=_pad1(sim.app_of_flow, F, 0),
        app_of_inst=_pad1(sim.app_of_inst, I, 0),
        n_apps=A,
        sin_amp=_pad2(sim.sin_amp, S, L),
        sin_omega=_pad2(sim.sin_omega, S, L),
        sin_phase=_pad2(sim.sin_phase, S, L),
        ev_t0=_pad1(sim.ev_t0, E, np.inf),
        ev_t1=_pad1(sim.ev_t1, E, np.inf),
        ev_link=_pad1(sim.ev_link, E, 0),
        ev_scale=_pad1(sim.ev_scale, E, 1.0),
        route_bank=route_bank,
        route_t=route_t,
        route_state=route_state,
    )


def stack_sims(
    sims: Sequence[CompiledSim], shape: FleetShape | None = None
) -> tuple[CompiledSim, FleetShape]:
    """Pad every sim to a common shape and stack into one batched pytree
    (every array leaf gains a leading scenario axis)."""
    if not sims:
        raise ValueError("empty fleet")
    shape = FleetShape.cover(sims) if shape is None else shape
    padded = [pad_sim(s, shape, tuples_per_mb=1.0) for s in sims]
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.asarray(np.stack(xs)), *padded)
    return stacked, shape


# field -> (padded-dim axes, pad value); dims keyed into {F, L, I, S, E}.
# A staging row never slice-assigned from a real scenario keeps exactly
# these pad values — which makes it an *inert scenario*: zero generation
# and demand, huge-capacity INTERNAL links no solver binds on, never-
# active events. Spare capacity rows are therefore harmless to run and
# their outputs are dropped on return.
_FIELD_SPECS: dict[str, tuple[tuple[str, ...], float]] = {
    "R": (("F", "L"), 0.0),
    "caps": (("L",), _PAD_CAP),
    "kinds": (("L",), int(LinkKind.INTERNAL)),
    "has_links": (("F",), False),
    "M_in": (("I", "F"), 0.0),
    "w_out": (("I", "F"), 0.0),
    "p_in": (("F",), 0.0),
    "proc_rate": (("I",), 0.0),
    "selectivity": (("I",), 0.0),
    "gen_rate": (("I",), 0.0),
    "is_join": (("I",), False),
    "is_sink": (("I",), False),
    "join_dst": (("F",), False),
    "droppable": (("F",), False),
    "dst_of_flow": (("F",), 0),
    "src_of_flow": (("F",), 0),
    "w_of_flow": (("F",), 0.0),
    "path_w": (("F",), 0.0),
    "app_of_flow": (("F",), 0),
    "app_of_inst": (("I",), 0),
    "sin_amp": (("S", "L"), 0.0),
    "sin_omega": (("S", "L"), 0.0),
    "sin_phase": (("S", "L"), 0.0),
    "ev_t0": (("E",), np.inf),
    "ev_t1": (("E",), np.inf),
    "ev_link": (("E",), 0),
    "ev_scale": (("E",), 1.0),
    # route bank: pad states are all-zero (never selected) and pad
    # intervals never activate; static-routing members of a rerouting
    # bucket get their base R written into slot 0 by the staging fill
    # (see _fill_bucket / _pad_route_fields)
    "route_bank": (("SR", "F", "L"), 0.0),
    "route_t": (("SR",), np.inf),
    "route_state": (("SR",), 0),
}


@dataclasses.dataclass
class CampaignResult:
    """Per-scenario metric summary of a streaming campaign.

    ``metrics`` is the ``[N, len(CAMPAIGN_METRICS)]`` matrix produced by the
    on-device epilogue, in scenario input order — the only per-scenario
    array a campaign retains by default. Throughput columns are MB-based
    (one padded program serves mixed tuple densities); the tuple-rate
    properties apply the exact per-scenario ``tuples_per_mb`` scalar
    host-side. ``results`` holds full per-scenario :class:`SimResult`
    trajectories only when the caller opted in
    (``retain_trajectories=True``) — otherwise ``None``, and no ``[T, …]``
    array ever left the device.

    ``failures`` is the structured quarantine report: one
    :class:`~repro.streams.faults.FailureRecord` per scenario the
    resilience layer gave up on (retries exhausted, or a non-finite
    metric row isolated by bisection). A quarantined scenario's
    ``metrics`` row is all-NaN; every other row is exactly what a
    fault-free campaign would have produced.
    """

    metrics: np.ndarray           # [N, n_metrics], MB-based
    tuples_per_mb: np.ndarray     # [N] exact per-scenario conversion
    dt: float
    policy: str
    results: list[SimResult] | None = None
    failures: list[FailureRecord] = dataclasses.field(default_factory=list)

    def metric(self, name: str) -> np.ndarray:
        """[N] column of ``metrics`` by :data:`CAMPAIGN_METRICS` name."""
        return self.metrics[:, metric_index(name)]

    @property
    def quarantined(self) -> np.ndarray:
        """[K] sorted scenario indices quarantined by the resilience
        layer (their ``metrics`` rows are NaN)."""
        return np.asarray(sorted({f.scenario for f in self.failures}), int)

    @property
    def throughput_tps(self) -> np.ndarray:
        """[N] post-warmup mean sink throughput, tuples/s."""
        return self.metric("avg_tput_mb_s") * self.tuples_per_mb

    @property
    def final_throughput_tps(self) -> np.ndarray:
        """[N] smoothed end-of-run sink throughput, tuples/s."""
        return self.metric("final_tput_mb_s") * self.tuples_per_mb

    @property
    def avg_latency_s(self) -> np.ndarray:
        return self.metric("avg_latency_s")

    @property
    def utilization(self) -> np.ndarray:
        return self.metric("utilization")

    @property
    def dip_depth(self) -> np.ndarray:
        return self.metric("dip_depth")

    @property
    def recovery_time_s(self) -> np.ndarray:
        return self.metric("recovery_time_s")


# ------------------------------------------------------------- checkpoints
# A campaign checkpoint is a directory: `manifest.jsonl` (one JSON line
# per completed chunk: campaign fingerprint, job index, scenario indices,
# slab filename, failures) plus one `chunk_<fp8>_<job>.npy` float32 slab
# per chunk, written BEFORE its manifest line — a manifest entry therefore
# implies its slab exists, and a kill between the two costs one chunk of
# re-work, never a torn read. Filenames carry the fingerprint prefix so a
# stale campaign's chunks can never collide with the current one's.

def _campaign_fingerprint(sims: Sequence[CompiledSim], jobs, cap_rows,
                          plan, base_key, qcap, x_fixed) -> str:
    """Hex digest pinning everything that determines a campaign's metric
    rows: run parameters, bucket plan + chunking structure, every
    scenario's staged field bytes, and the fixed-rate vectors. Any drift
    ⇒ different fingerprint ⇒ checkpoint entries are ignored rather than
    restored into the wrong campaign."""
    h = zlib.crc32(repr(base_key).encode())
    h = zlib.crc32(repr(float(qcap)).encode(), h)
    h = zlib.crc32(repr([(bi, tuple(idxs)) for bi, idxs in jobs]).encode(), h)
    h = zlib.crc32(repr(list(cap_rows)).encode(), h)
    h = zlib.crc32(repr([dataclasses.astuple(s) for _, s in plan]).encode(), h)
    for s in sims:
        h = zlib.crc32(_sim_content_sig(s).to_bytes(8, "little"), h)
    if x_fixed is not None:
        for xf in x_fixed:
            a = np.ascontiguousarray(np.asarray(xf, np.float32))
            h = zlib.crc32(a.tobytes(), h)
    return f"{h:08x}"


def _checkpoint_load(path: str, fp: str, jobs, n_metrics: int
                     ) -> dict[int, tuple[np.ndarray, list[FailureRecord]]]:
    """Restorable chunks: {job index: (metric slab, failures)} for every
    manifest entry matching this campaign's fingerprint whose slab exists
    and whose scenario list still matches the job structure. Torn or
    foreign lines are skipped, not fatal — resume is best-effort."""
    done: dict[int, tuple[np.ndarray, list[FailureRecord]]] = {}
    mpath = os.path.join(path, "manifest.jsonl")
    if not os.path.exists(mpath):
        return done
    with open(mpath) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
            except json.JSONDecodeError:
                continue  # torn tail from a kill mid-append
            if e.get("fp") != fp:
                continue
            j = int(e["job"])
            if j >= len(jobs) or [int(i) for i in e["idxs"]] != list(
                    jobs[j][1]):
                continue
            fn = os.path.join(path, os.path.basename(e["file"]))
            if not os.path.exists(fn):
                continue
            slab = np.load(fn)
            if slab.shape != (len(e["idxs"]), n_metrics):
                continue
            fails = [FailureRecord(int(r[0]), str(r[1]), str(r[2]),
                                   int(r[3]))
                     for r in e.get("failures", [])]
            done[j] = (slab, fails)
    return done


def _checkpoint_append(path: str, fp: str, j: int, idxs,
                       slab: np.ndarray,
                       fails: Sequence[FailureRecord]) -> None:
    fn = f"chunk_{fp}_{j:05d}.npy"
    np.save(os.path.join(path, fn), slab)
    entry = {"fp": fp, "job": j, "idxs": [int(i) for i in idxs],
             "file": fn,
             "failures": [[f.scenario, f.stage, f.reason, f.attempts]
                          for f in fails]}
    with open(os.path.join(path, "manifest.jsonl"), "a") as f:
        f.write(json.dumps(entry) + "\n")
        f.flush()
        os.fsync(f.fileno())


class FleetRunner:
    """Persistent packed-fleet executor (see module docstring).

    One runner amortizes three caches across calls — all held *per
    instance*, so two runners (e.g. with different ``max_buckets`` or
    planner constants) can never poison each other's entries or
    no-recompile assertions:

    * the jitted executable per (pack signature, policy, solver, n_ticks,
      upd_every, dt, device count) key (``compile_cache_size`` exposes the
      XLA cache-miss count across them),
    * the numpy staging buffers per (bucket shape, members, rows),
    * the bucket plan per (fleet shape multiset, policy).

    ``fused=True`` (default) runs every bucket of a plan inside one jitted
    executable: a warm fleet run is exactly ONE kernel dispatch.
    ``fused=False`` dispatches each bucket as its own executable — the
    per-bucket parity oracle (and the mode the ``fleet_dispatch_floor``
    bench uses to measure per-dispatch overhead). ``simulate_many`` routes
    through one module-level instance. ``last_stats`` reports the dispatch
    count, bucket structure, and padded row counts of the latest run.
    """

    # staging entries kept before the oldest are evicted: each holds one
    # [B, F, L]-scale set of numpy buffers, so an unbounded cache would grow
    # for the life of the process across a many-shaped sweep
    MAX_STAGED = 32

    def __init__(self, max_buckets: int = 4, fused: bool = True,
                 tick_overhead: float | None = None,
                 fingerprint: str = "content"):
        if fingerprint not in ("content", "identity", "off"):
            raise ValueError(f"fingerprint must be 'content', 'identity' or "
                             f"'off', got {fingerprint!r}")
        self.max_buckets = int(max_buckets)
        self.fused = bool(fused)
        self.tick_overhead = (_default_tick_overhead()
                              if tick_overhead is None
                              else float(tick_overhead))
        # staging-reuse fingerprint for the materialized warm path:
        # "content" (default) = object identity + crc32 over every field's
        # bytes (catches in-place mutation between warm calls);
        # "identity" = object identity only — skips the O(corpus) hashing
        # when the caller guarantees scenarios are never mutated in place;
        # "off" = no reuse at all — every call restages into the
        # preallocated buffers (what the streaming campaign path does by
        # construction: chunks are always staged fresh, so it never hashes)
        self.fingerprint = fingerprint
        self._staging: dict[tuple, dict[str, np.ndarray]] = {}
        self._stacked: dict[tuple, CompiledSim] = {}
        self._device: dict[tuple, CompiledSim] = {}  # device-resident packs
        self._filled: dict[tuple, list] = {}  # staging key -> sim weakrefs
        self._plan_cache: dict[tuple, list[tuple[list[int], FleetShape]]] = {}
        self._executables: dict[tuple, "jax.stages.Wrapped"] = {}
        self._shardings: dict[int, tuple] = {}
        # campaign ping/pong staging slots: (shape, rows, phase) -> buffers
        self._campaign_bufs: dict[tuple, dict[str, np.ndarray]] = {}
        self.last_stats: dict = {}

    # ---------------------------------------------------------- planning
    def plan(self, sims: Sequence[CompiledSim],
             policy: str = "tcp") -> list[tuple[list[int], FleetShape]]:
        """Bucket assignment for a fleet: list of (scenario indices, padded
        bucket shape). Cached per (shape multiset, policy) — the FLOP model
        is policy-aware."""
        key = (tuple(dataclasses.astuple(_sim_shape(s)) for s in sims),
               policy)
        plan = self._plan_cache.get(key)
        if plan is None:
            plan = _plan_buckets(sims, self.max_buckets,
                                 exact_apps=(policy == "appfair"),
                                 policy=policy,
                                 tick_overhead=self.tick_overhead)
            self._plan_cache[key] = plan
        return plan

    def _sharding(self, n_shards: int):
        """(batch, replicated) shardings for the scenario axis, or (None,
        None) single-device."""
        if n_shards <= 1:
            return None, None
        cached = self._shardings.get(n_shards)
        if cached is None:
            mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("scenarios",))
            cached = (NamedSharding(mesh, PartitionSpec("scenarios")),
                      NamedSharding(mesh, PartitionSpec()))
            self._shardings[n_shards] = cached
        return cached

    # ----------------------------------------------------------- staging
    def _fill_bucket(self, bufs: dict[str, np.ndarray],
                     sims: list[CompiledSim], shape: FleetShape,
                     rows: int) -> dict[str, np.ndarray]:
        """Reset + slice-assign ``sims`` into (re)allocated ``rows``-row
        numpy buffers (one per ``_FIELD_SPECS`` field). Spare rows keep
        their pad values — inert scenarios. Shared by the warm-path
        staging cache and the campaign ping/pong slots."""
        dims = {"F": shape.n_flows, "L": shape.n_links,
                "I": shape.n_insts,
                "S": shape.n_sins, "E": shape.n_events,
                "SR": shape.n_route_states}
        for field, (axes, pad) in _FIELD_SPECS.items():
            first = np.asarray(getattr(sims[0], field))
            full = (rows,) + tuple(dims[a] for a in axes)
            buf = bufs.get(field)
            if buf is None or buf.shape != full or buf.dtype != first.dtype:
                buf = np.empty(full, first.dtype)
                bufs[field] = buf
            buf.fill(pad)
            for b, s in enumerate(sims):
                a = np.asarray(getattr(s, field))
                buf[(b, *map(lambda n: slice(0, n), a.shape))] = a
        if shape.n_route_states > 0:
            # static-routing members of a rerouting bucket: their per-tick
            # state lookup clamps to slot 0, which must hold their base R
            # (all-zero pad rows would route nothing)
            bank = bufs["route_bank"]
            for b, s in enumerate(sims):
                if s.route_bank.shape[0] == 0:
                    a = np.asarray(s.R)
                    bank[b, 0, :a.shape[0], :a.shape[1]] = a
        return {field: bufs[field] for field in _FIELD_SPECS}

    def _stack_bucket(self, sims: list[CompiledSim], shape: FleetShape,
                      idxs: list[int], rows: int) -> tuple[CompiledSim,
                                                           tuple, bool]:
        """Stack a bucket into preallocated numpy staging buffers of
        ``rows`` ≥ len(sims) batch rows (reset + slice-assign; no per-sim
        np.pad allocations on repeat calls). Spare rows keep their pad
        values — inert scenarios, dropped on return. When the bucket holds
        the *same scenario objects with the same field bytes* as the
        previous call (the steady state of a repeat study) the filled
        buffers are reused outright — the warm path re-stacks nothing.
        The key includes the bucket's member
        indices: two buckets of one fleet can share a padded shape and
        batch size, and a shape-only key would make them overwrite each
        other's staging every call (silently losing the warm-path reuse
        for both). Returns (stacked numpy pack, staging key, freshly
        staged) — the caller keys its device-resident copy on the same
        staging key and refreshes it only when the numpy side changed."""
        B = len(sims)
        key = (dataclasses.astuple(shape), tuple(idxs), rows)
        entry = self._filled.get(key) if self.fingerprint != "off" else None
        # reuse requires the same scenario OBJECTS *and* (by default) the
        # same field bytes: object identity alone is unsound — callers may
        # legally mutate a scenario's arrays in place between warm calls
        # (dataclasses are not frozen deep), and serving the previous
        # staging would silently replay the pre-mutation fleet. The
        # content signature (crc32 over every staged field) catches that;
        # corpus-scale scenarios hash in microseconds, far below one
        # restage — but it IS O(corpus) host work per warm call, so the
        # ``fingerprint`` knob lets callers with an immutability guarantee
        # drop to identity-only (and "off" disables reuse outright; the
        # campaign streaming path never enters this cache at all).
        if entry is not None:
            refs, sigs = entry
            if len(refs) == B and all(
                    r() is s for r, s in zip(refs, sims)) and (
                    self.fingerprint == "identity" or all(
                        g == _sim_content_sig(s)
                        for g, s in zip(sigs, sims))):
                # LRU touch: move the hit key to the back so steady repeat
                # studies never lose their staging to a sweep's churn
                self._staging[key] = self._staging.pop(key)
                return self._stacked[key], key, False
        # bounded cache: drop the oldest staged buckets (and any whose sims
        # were garbage-collected) before staging a new one
        dead = [k for k, (rs, _) in self._filled.items()
                if any(r() is None for r in rs)]
        evict = dead + [k for k in self._staging
                        if k not in dead][:max(
                            0, len(self._staging) - len(dead)
                            - self.MAX_STAGED + 1)]
        for k in evict:
            if k != key:
                self._staging.pop(k, None)
                self._stacked.pop(k, None)
                self._filled.pop(k, None)
        # restaging mutates the numpy buffers in place: every device copy
        # of this key (any n_shards variant) and of evicted keys is stale
        for dk in [d for d in self._device if d[0] == key or d[0] in evict]:
            self._device.pop(dk, None)
        bufs = self._staging.setdefault(key, {})
        leaves = self._fill_bucket(bufs, sims, shape, rows)
        stacked = CompiledSim(tuples_per_mb=1.0, n_apps=shape.n_apps,
                              **leaves)
        self._stacked[key] = stacked
        self._filled[key] = ([weakref.ref(s) for s in sims],
                             [_sim_content_sig(s) for s in sims]
                             if self.fingerprint == "content" else
                             [None] * len(sims))
        return stacked, key, True

    # --------------------------------------------------------- executable
    def _executable(self, key, n_shards: int, policy: str,
                    n_ticks: int, dt: float, upd_every: int, alpha: float,
                    n_groups: int, solver: str, t_event: float = 0.0):
        """Build (and cache) the jitted entry point for one pack of
        ``n_buckets`` buckets.

        The executable takes ``(packs, xfs, enfs, qcap)`` — tuples with one
        entry per bucket — and runs each bucket's vmap-over-scan *inside
        the same XLA program*, so one call is one kernel dispatch whatever
        the internal bucket structure. Each bucket keeps its own scan: a
        single scan over the tuple of bucket carries measured slower on
        CPU *and* lost bitwise parity with per-bucket dispatch (XLA fuses
        ops across the bucket bodies, re-associating reductions), while
        per-bucket scans inside one program are bitwise-identical to
        separate executables.

        With ``n_shards`` > 1 every bucket's scenario axis is split across
        local devices as plain SPMD sharding (``jit`` + ``in_shardings``;
        the fused fixed-trip max-min solver left no data-dependent control
        flow, see PR 4 — ``shard_map`` is unnecessary). The stacked packs
        arrive pre-placed under the same shardings and are *not* donated:
        the runner re-passes the identical device buffers on every warm
        call, so the steady state pays zero H2D transfer — donation would
        consume them (see module docstring).
        """
        fn = self._executables.get(key)
        if fn is not None:
            return fn

        def one(sim, xf, enf, q):
            return _run(sim, policy, n_ticks, dt, upd_every, x_fixed=xf,
                        alpha=alpha, n_groups=n_groups, qcap=q,
                        solver=solver, enforce=enf,
                        with_metrics=True, t_event=t_event)

        def impl(packs, xfs, enfs, qcap):
            outs = []
            for stacked, xf, enf in zip(packs, xfs, enfs):
                if xf is None:
                    outs.append(jax.vmap(
                        lambda s, e, q: one(s, None, e, q),
                        in_axes=(0, 0, None))(stacked, enf, qcap))
                else:
                    outs.append(jax.vmap(one, in_axes=(0, 0, 0, None))(
                        stacked, xf, enf, qcap))
            return tuple(outs)

        batch, rep = self._sharding(n_shards)
        if batch is not None:
            fn = jax.jit(impl, in_shardings=(batch, batch, batch, rep))
        else:
            fn = jax.jit(impl)
        self._executables[key] = fn
        return fn

    # ------------------------------------------------------------ running
    def run(
        self,
        sims: Sequence[CompiledSim],
        policy: str = "tcp",
        seconds: float = 600.0,
        dt: float = 0.5,
        upd_every: int | None = None,
        x_fixed: Sequence[np.ndarray] | None = None,
        alpha: float = 0.5,
        n_groups: int = 8,
        qcap: float = 8.0,
        solver: str = "sort",
        shard: bool = True,
        t_event: float = 0.0,
    ) -> list[SimResult]:
        """Run the whole fleet as one fused executable (``fused=True``) or
        bucket-by-bucket (``fused=False``); one :class:`SimResult` per
        scenario (input order), each sliced back to its true [L]/[A]
        shapes — element-wise equal to ``simulate(sims[b], ...)`` for every
        policy (appfair buckets by exact app count).

        With >1 local device (e.g. ``--xla_force_host_platform_device_count``
        on CPU, or a TPU slice) and ``shard=True``, each bucket's scenario
        axis is sharded across devices (bucket rows are padded with inert
        scenarios up to a device multiple and dropped on return).
        """
        if not sims:
            raise ValueError("empty fleet")
        sims = list(sims)
        if x_fixed is not None and len(x_fixed) != len(sims):
            raise ValueError("x_fixed must give one rate vector per scenario")
        n_ticks = int(round(smoke_seconds(seconds) / dt))
        upd_every = resolve_upd_every(policy, dt, upd_every)
        n_dev = len(jax.devices()) if shard else 1

        plan = self.plan(sims, policy)
        row_counts = [_round_rows(len(idxs), n_dev) for idxs, _ in plan]
        n_shards = n_dev if (n_dev > 1
                             and all(r % n_dev == 0 for r in row_counts)
                             ) else 1
        batch_sh, _ = self._sharding(n_shards)
        packs, xfs, enfs = [], [], []
        for (idxs, shape), rows in zip(plan, row_counts):
            stacked, skey, fresh = self._stack_bucket(
                [sims[i] for i in idxs], shape, idxs, rows)
            # device-resident pack: pushed (pre-sharded) once per staging,
            # re-passed verbatim on warm calls — zero per-call transfer
            # (restaging purges every device variant of the key)
            dkey = (skey, n_shards)
            dev = self._device.get(dkey)
            if dev is None:
                dev = (jax.device_put(stacked, batch_sh)
                       if batch_sh is not None else
                       jax.tree_util.tree_map(jnp.asarray, stacked))
                self._device[dkey] = dev
            packs.append(dev)
            if x_fixed is None:
                xfs.append(None)
            else:
                # rebuilt (and re-transferred) per call on purpose: the
                # staging fingerprint covers scenario identity, not the
                # x_fixed *values*, so caching these on the staging key
                # would serve stale rate vectors across sweeps
                xf = np.zeros((rows, shape.n_flows), np.float32)
                for b, i in enumerate(idxs):
                    xf[b, :len(x_fixed[i])] = np.asarray(x_fixed[i],
                                                         np.float32)
                xfs.append(xf)
            # per-scenario capacity-enforcement gate: scheduled scenarios
            # enforce caps(t) per tick; static (and inert spare) rows keep
            # exact static semantics even inside a scheduled bucket
            enf = np.zeros(rows, bool)
            for b, i in enumerate(idxs):
                enf[b] = sims[i].is_dynamic
            enfs.append(enf)
        pack_sig = tuple((dataclasses.astuple(shape), rows)
                         for (_, shape), rows in zip(plan, row_counts))
        base_key = (policy, n_ticks, dt, upd_every, alpha, n_groups, solver,
                    n_shards, x_fixed is not None, float(t_event))

        if self.fused:
            fn = self._executable(
                base_key + (pack_sig,), n_shards, policy,
                n_ticks, dt, upd_every, alpha, n_groups, solver,
                t_event=float(t_event))
            outs = fn(tuple(packs), tuple(xfs), tuple(enfs),
                      jnp.float32(qcap))
            n_dispatches = 1
        else:
            # per-bucket oracle: one executable (and one dispatch) per
            # bucket; jax dispatch is async, so bucket k+1's staging
            # overlaps bucket k's compute
            outs = []
            for pack, xf, enf, sig in zip(packs, xfs, enfs, pack_sig):
                fn = self._executable(
                    base_key + (sig,), n_shards, policy, n_ticks,
                    dt, upd_every, alpha, n_groups, solver,
                    t_event=float(t_event))
                outs.append(fn((pack,), (xf,), (enf,),
                               jnp.float32(qcap))[0])
            n_dispatches = len(plan)

        self.last_stats = {
            "n_dispatches": n_dispatches,
            "n_buckets": len(plan),
            "n_scenarios": len(sims),
            "rows": row_counts,
            "bucket_shapes": [dataclasses.astuple(s) for _, s in plan],
            "policy": policy,
        }

        out: list[SimResult | None] = [None] * len(sims)
        total_rebuilds = 0
        for (idxs, _), ys in zip(plan, outs):
            host = [np.asarray(y) for y in ys]
            rebuilds = host[4]
            for b, i in enumerate(idxs):
                out[i] = result_from_padded_row(sims[i], b, dt, *host)
                total_rebuilds += int(rebuilds[b].sum())
        self.last_stats["order_rebuilds"] = total_rebuilds
        return out  # type: ignore[return-value]

    # ---------------------------------------------------------- campaigns
    def run_campaign(
        self,
        sims: Sequence[CompiledSim],
        policy: str = "tcp",
        seconds: float = 600.0,
        dt: float = 0.5,
        upd_every: int | None = None,
        x_fixed: Sequence[np.ndarray] | None = None,
        alpha: float = 0.5,
        n_groups: int = 8,
        qcap: float = 8.0,
        solver: str = "sort",
        shard: bool = True,
        t_event: float = 0.0,
        chunk_rows: int | str = 64,
        retain_trajectories: bool = False,
        faults: FaultPlan | None = None,
        max_retries: int = 3,
        retry_backoff_s: float = 0.05,
        retry_backoff_cap_s: float = 1.0,
        transfer_timeout_s: float | None = 60.0,
        checkpoint: str | os.PathLike | None = None,
        finite_check: bool = True,
    ) -> CampaignResult:
        """Streaming campaign dispatch: run an arbitrarily large fleet in
        fixed-shape chunks with bounded host/device memory (see module
        docstring §streaming). The bucket plan is computed over the WHOLE
        campaign, then each bucket's members run in chunks of at most
        ``chunk_rows`` padded rows — every chunk of a bucket shares one
        compiled executable, the ragged last chunk riding on inert spare
        rows. ``chunk_rows="auto"`` sizes chunks per bucket from the
        backend calibration (:func:`calibrate_backend`): the smallest
        chunk keeping fixed per-chunk overhead a small fraction of its
        modeled compute.

        Execution is a three-stage pipeline per device stream — host pack
        → H2D transfer → compute. A dedicated transfer worker runs
        ``jax.device_put`` off the dispatch thread, so chunk *k+1*'s bytes
        are resident before chunk *k+1* is dispatched and the copy itself
        overlaps chunk *k*'s compute; the host side keeps three rotating
        numpy slots per stream (one per pipeline stage — ``device_put``
        may zero-copy alias aligned host buffers on CPU, so a slot is
        reused only after its occupant's execution was collected), the
        device side holds at most the prefetched pack plus the in-flight
        one. With >1 local device and
        ``shard=True`` the *chunk stream* is sharded round-robin across
        devices (each chunk runs whole on one device; only the ``[rows,
        n_metrics]`` summaries are gathered) — chunk shapes are quantized
        independent of device count, so campaign metrics are
        bitwise-identical at every device count.

        Returns a :class:`CampaignResult`; with ``retain_trajectories=True``
        the full per-scenario :class:`SimResult` list is materialized too
        (trajectory transfer re-enabled — only for small campaigns).
        ``last_stats`` gains ``peak_staged_rows`` / ``peak_staged_bytes``,
        the pipeline wall-time split (``stage_s`` / ``transfer_s`` /
        ``transfer_wait_s`` / ``dispatch_s`` / ``block_s``),
        ``overlap_fraction`` (share of *hideable* staging hidden behind
        in-flight compute; 1.0 when nothing was hideable — a single-chunk
        campaign has no compute to hide behind) and ``transfer_overlap``
        (share of H2D copy time not re-paid as dispatch-thread waiting).

        **Resilience** (all host-side; the compiled executables are
        untouched and a fault-free campaign is bitwise-identical with the
        guards on): a chunk whose pack/transfer/dispatch raises — or
        whose transfer exceeds ``transfer_timeout_s`` — is retried
        synchronously with capped exponential backoff
        (``max_retries`` × ``retry_backoff_s``…``retry_backoff_cap_s``);
        a chunk that exhausts retries, or whose ``[rows, n_metrics]``
        epilogue slab contains non-finite values (``finite_check``; +inf
        in the recovery column is legitimate), is bisected
        scenario-by-scenario to isolate the poisoned rows. Quarantined
        scenarios get all-NaN metric rows and a
        :class:`~repro.streams.faults.FailureRecord` in
        ``CampaignResult.failures`` while the rest of the campaign
        completes bitwise-clean. With ``checkpoint=dir`` every collected
        chunk's slab is appended to disk and a re-run over the same
        corpus/parameters (same fingerprint) restores completed chunks
        bitwise without re-dispatching them. ``faults`` injects a
        deterministic :class:`~repro.streams.faults.FaultPlan` to
        exercise all of the above. On *any* error (including
        KeyboardInterrupt) the pipeline tears down cleanly and
        ``last_stats`` reports ``{"status": "failed", ...}`` with the
        progress made.
        """
        if not sims:
            raise ValueError("empty campaign")
        auto_chunk = chunk_rows == "auto"
        if isinstance(chunk_rows, str) and not auto_chunk:
            raise ValueError(f"chunk_rows must be an int or 'auto', "
                             f"got {chunk_rows!r}")
        if not auto_chunk and chunk_rows < 1:
            raise ValueError("chunk_rows must be >= 1")
        sims = list(sims)
        if x_fixed is not None and len(x_fixed) != len(sims):
            raise ValueError("x_fixed must give one rate vector per scenario")
        if checkpoint is not None and retain_trajectories:
            raise ValueError(
                "checkpoint + retain_trajectories is unsupported: resumed "
                "chunks restore metric slabs only, never trajectories")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        n_ticks = int(round(smoke_seconds(seconds) / dt))
        upd_every = resolve_upd_every(policy, dt, upd_every)
        n_dev = len(jax.devices()) if shard else 1

        t_wall0 = time.perf_counter()
        calib = calibrate_backend()
        plan = self.plan(sims, policy)
        # fixed padded row count per bucket, chunks BALANCED within it:
        # naive fixed-size chunking leaves the last chunk of each bucket
        # mostly inert but full price in padded rows (256 scenarios / 64
        # chunk_rows over 3 buckets streams 384 padded rows against the
        # materialized path's 264 — measurably slower for no memory win),
        # so each bucket splits into ceil(members / chunk_rows) near-equal
        # chunks all sharing ONE quantized row count — one executable per
        # bucket, inert waste bounded by the quantum, not by chunk_rows
        jobs: list[tuple[int, list[int]]] = []  # (bucket index, member idxs)
        cap_rows: list[int] = []
        target_rows: list[int] = []
        for bi, (idxs, shape) in enumerate(plan):
            target = (_auto_chunk_rows(shape, policy, n_ticks, calib)
                      if auto_chunk else int(chunk_rows))
            target_rows.append(target)
            n_chunks_b = -(-len(idxs) // max(target, 1))
            per = -(-len(idxs) // n_chunks_b)
            # quantized independent of device count: every chunk runs
            # WHOLE on one device, so 1-device and N-device campaigns
            # share identical padded shapes (hence identical programs and
            # bitwise-identical metrics) — the shard changes where a chunk
            # runs, never what it computes
            cap_rows.append(_round_rows(per, 1))
            jobs.extend((bi, idxs[lo:lo + per])
                        for lo in range(0, len(idxs), per))
        # scenario-axis shard of the chunk stream: chunk j runs on device
        # j % n_streams, each stream with its own ping/pong pipeline. On a
        # real multi-host mesh the same round-robin rule partitions the
        # job list per host (`jax.distributed`-shaped: local devices only,
        # metric rows merged by scenario index).
        n_streams = max(1, min(n_dev, len(jobs)))
        stream_sh = [SingleDeviceSharding(d)
                     for d in jax.devices()[:n_streams]]
        base_key = (policy, n_ticks, dt, upd_every, alpha, n_groups, solver,
                    1, x_fixed is not None, float(t_event))
        fns = [self._executable(
                   base_key + (((dataclasses.astuple(shape), rows),),),
                   1, policy, n_ticks, dt, upd_every, alpha,
                   n_groups, solver, t_event=float(t_event))
               for (_, shape), rows in zip(plan, cap_rows)]

        n_metrics = len(CAMPAIGN_METRICS)
        metrics_all = np.empty((len(sims), n_metrics), np.float32)
        results: list[SimResult | None] | None = (
            [None] * len(sims) if retain_trajectories else None)
        stage_s = dispatch_s = block_s = 0.0
        transfer_s = transfer_wait_s = 0.0
        hidden_stage_s = hideable_stage_s = 0.0
        peak_rows = peak_bytes = 0
        inflight_total = 0
        # per-stream pipeline state: at most ONE submitted-but-undispatched
        # transfer (`pending`), at most two dispatched-but-uncollected
        # chunks (`inflight`), and a staged-chunk counter driving the
        # stream's host ping/pong phase
        pending: list[tuple | None] = [None] * n_streams
        inflight: list[list] = [[] for _ in range(n_streams)]
        staged_n = [0] * n_streams

        # ---- resilience state (inert on the fault-free path) ----
        failures: list[FailureRecord] = []
        n_retries = n_recovered = n_dispatched = 0
        chunks_done = 0
        rec_col = metric_index("recovery_time_s")

        # ---- checkpoint/resume ----
        ckpt_dir = ckpt_fp = None
        done_jobs: dict[int, tuple[np.ndarray, list[FailureRecord]]] = {}
        if checkpoint is not None:
            ckpt_dir = os.fspath(checkpoint)
            os.makedirs(ckpt_dir, exist_ok=True)
            ckpt_fp = _campaign_fingerprint(
                sims, jobs, cap_rows, plan, base_key, qcap, x_fixed)
            done_jobs = _checkpoint_load(ckpt_dir, ckpt_fp, jobs, n_metrics)
            for j, (slab, fails) in done_jobs.items():
                for b, i in enumerate(jobs[j][1]):
                    metrics_all[i] = slab[b]  # np.save/load f32: bitwise
                failures.extend(fails)
        n_resumed = len(done_jobs)

        def _fire(stage, j):
            if faults is not None:
                faults.fire(stage, j)

        def _slab_rows_ok(m):
            # [n, n_metrics] -> [n] bool. NaN is poison everywhere; +inf
            # is poison everywhere EXCEPT the recovery column, where it
            # legitimately means "never recovered within the horizon"
            ok = np.isfinite(m)
            ok[:, rec_col] = ~np.isnan(m[:, rec_col])
            return ok.all(axis=1)

        def _chunk_complete(j, idxs):
            nonlocal chunks_done
            chunks_done += 1
            if ckpt_fp is not None:
                idx_set = set(idxs)
                fl = [f for f in failures if f.scenario in idx_set]
                _checkpoint_append(ckpt_dir, ckpt_fp, j, idxs,
                                   metrics_all[list(idxs)].copy(), fl)

        def _h2d(host_pack, sh, j):
            # transfer worker. NOTE: on CPU, device_put zero-copy aliases
            # 64-byte-aligned numpy buffers instead of copying (measured),
            # so a resolved future does NOT mean the host slot is free —
            # the triple-buffered slot rotation below owns that invariant
            t0 = time.perf_counter()
            _fire("transfer", j)
            dev = jax.device_put(host_pack, sh)
            jax.block_until_ready(dev)
            return dev, time.perf_counter() - t0

        def _collect_oldest(s):
            nonlocal block_s, inflight_total
            j, bi, idxs, chunk, outs = inflight[s].pop(0)
            t0 = time.perf_counter()
            # block ONLY on the [rows, n_metrics] epilogue leaf; the [T, …]
            # trajectory outputs stay on device and free when `outs` drops
            try:
                m = np.asarray(outs[6])
            except Exception as e:  # noqa: BLE001 — route to recovery
                inflight_total -= 1
                block_s += time.perf_counter() - t0
                _recover_chunk(bi, j, idxs, chunk, e)
                return
            if faults is not None and faults.poison:
                # copy before poisoning: np.asarray of a device array may
                # be a read-only (or aliasing) view
                m = np.array(m)
                m[:len(idxs)][faults.poison_mask(idxs)] = np.nan
            bad = None
            if finite_check:
                ok = _slab_rows_ok(m[:len(idxs)])
                if not ok.all():
                    bad = ~ok
            for b, i in enumerate(idxs):
                if bad is None or not bad[b]:
                    metrics_all[i] = m[b]
            if results is not None:
                host = [np.asarray(o) for o in outs[:6]]
                for b, i in enumerate(idxs):
                    if bad is None or not bad[b]:
                        results[i] = result_from_padded_row(
                            chunk[b], b, dt, *host, m)
            inflight_total -= 1
            block_s += time.perf_counter() - t0
            if bad is not None:
                # non-finite rows: good rows above are final (vmap rows
                # are independent); bisect only the poisoned ones
                _bisect(bi, j,
                        [i for b, i in enumerate(idxs) if bad[b]],
                        [c for b, c in enumerate(chunk) if bad[b]])
            _chunk_complete(j, idxs)

        def _dispatch(s):
            nonlocal dispatch_s, transfer_s, transfer_wait_s
            nonlocal inflight_total, n_dispatched
            bi, j, idxs, chunk, fut = pending[s]
            pending[s] = None
            t0 = time.perf_counter()
            try:
                (pack, xf, enf), t_copy = (
                    fut.result() if transfer_timeout_s is None
                    else fut.result(timeout=transfer_timeout_s))
            except FuturesTimeoutError:
                transfer_wait_s += time.perf_counter() - t0
                # hung transfer: the worker may be wedged in a driver
                # call, so abandon the whole executor (the hung thread
                # leaks until it returns; its eventual device_put result
                # is dropped unread) and rebuild the pipeline on a fresh
                # one, then re-run the chunk synchronously
                _replace_executor()
                _recover_chunk(bi, j, idxs, chunk, TimeoutError(
                    f"H2D transfer of chunk {j} exceeded "
                    f"{transfer_timeout_s}s"))
                return
            except (Exception, FuturesCancelledError) as e:  # noqa: BLE001
                # CancelledError is a BaseException since 3.8 but here
                # only means "the watchdog replaced the executor while
                # this stream's copy was queued" — recoverable
                transfer_wait_s += time.perf_counter() - t0
                _recover_chunk(bi, j, idxs, chunk, e)
                return
            transfer_wait_s += time.perf_counter() - t0
            transfer_s += t_copy
            t0 = time.perf_counter()
            try:
                _fire("dispatch", j)
                outs = fns[bi]((pack,), (xf,), (enf,), jnp.float32(qcap))[0]
            except Exception as e:  # noqa: BLE001 — route to recovery
                dispatch_s += time.perf_counter() - t0
                _recover_chunk(bi, j, idxs, chunk, e)
                return
            n_dispatched += 1
            dispatch_s += time.perf_counter() - t0
            inflight[s].append((j, bi, idxs, chunk, outs))
            inflight_total += 1
            if len(inflight[s]) > 1:
                _collect_oldest(s)

        # ---- recovery: synchronous retry / bisect / quarantine ----
        # All recovery re-runs use the SAME per-bucket executable at the
        # SAME padded row count as the pipeline path — vmap rows are
        # independent and spare rows inert, so a scenario's metric row is
        # bitwise-identical whichever sub-chunk it rides in.

        def _replace_executor():
            ex_holder[0].shutdown(wait=False, cancel_futures=True)
            ex_holder[0] = ThreadPoolExecutor(max_workers=1,
                                              thread_name_prefix="h2d")

        def _stage_of(err):
            if isinstance(err, InjectedFault):
                return err.stage
            if isinstance(err, (TimeoutError, FuturesTimeoutError)):
                return "transfer"
            return "run"

        def _run_subset_once(bi, j, idxs, chunk, s):
            """One synchronous pack→transfer→dispatch→collect of a chunk
            subset. Staging goes into FRESH scratch buffers — never the
            rotating pipeline slots, which an in-flight (or abandoned)
            transfer may still alias."""
            nonlocal n_dispatched
            shape = plan[bi][1]
            rows = cap_rows[bi]
            _fire("pack", j)
            leaves = self._fill_bucket({}, chunk, shape, rows)
            stacked = CompiledSim(tuples_per_mb=1.0,
                                  n_apps=shape.n_apps, **leaves)
            xf = None
            if x_fixed is not None:
                xf = np.zeros((rows, shape.n_flows), np.float32)
                for b, i in enumerate(idxs):
                    xf[b, :len(x_fixed[i])] = np.asarray(x_fixed[i],
                                                         np.float32)
            enf = np.zeros(rows, bool)
            for b, sim in enumerate(chunk):
                enf[b] = sim.is_dynamic
            _fire("transfer", j)
            pack, xfd, enfd = jax.device_put((stacked, xf, enf),
                                             stream_sh[s])
            _fire("dispatch", j)
            outs = fns[bi]((pack,), (xfd,), (enfd,), jnp.float32(qcap))[0]
            n_dispatched += 1
            m = np.array(np.asarray(outs[6])[:len(idxs)])
            if faults is not None and faults.poison:
                m[faults.poison_mask(idxs)] = np.nan
            host = ([np.asarray(o) for o in outs[:6]]
                    if results is not None else None)
            return m, host

        def _try_subset(bi, j, idxs, chunk, s):
            """Run a subset with capped-exponential-backoff retries.
            Returns (m, host, err, attempts); err is the last exception
            when every attempt failed."""
            nonlocal n_retries
            err = None
            for attempt in range(max_retries + 1):
                if attempt:
                    n_retries += 1
                    time.sleep(min(retry_backoff_s * 2.0 ** (attempt - 1),
                                   retry_backoff_cap_s))
                try:
                    m, host = _run_subset_once(bi, j, idxs, chunk, s)
                    return m, host, None, attempt + 1
                except Exception as e:  # noqa: BLE001 — retried
                    err = e
            return None, None, err, max_retries + 1

        def _accept_rows(idxs, chunk, m, host, ok=None):
            for b, i in enumerate(idxs):
                if ok is None or ok[b]:
                    metrics_all[i] = m[b]
                    if results is not None and host is not None:
                        results[i] = result_from_padded_row(
                            chunk[b], b, dt, *host, m)

        def _quarantine(i, stage, reason, attempts):
            metrics_all[i] = np.nan
            if results is not None:
                results[i] = None
            failures.append(FailureRecord(scenario=int(i), stage=stage,
                                          reason=reason, attempts=attempts))

        def _bisect(bi, j, idxs, chunk):
            """Isolate poisoned scenarios: run halves (with retries);
            surviving rows are accepted, failing halves recurse down to
            single scenarios, which are quarantined."""
            if not idxs:
                return
            s = j % n_streams
            if len(idxs) == 1:
                m, host, err, attempts = _try_subset(bi, j, idxs, chunk, s)
                if err is not None:
                    _quarantine(idxs[0], _stage_of(err), repr(err), attempts)
                elif finite_check and not _slab_rows_ok(m)[0]:
                    _quarantine(idxs[0], "non_finite",
                                "non-finite values in metric epilogue row",
                                attempts)
                else:
                    _accept_rows(idxs, chunk, m, host)
                return
            mid = (len(idxs) + 1) // 2
            for lo, hi in ((0, mid), (mid, len(idxs))):
                sub_i, sub_c = idxs[lo:hi], chunk[lo:hi]
                m, host, err, _ = _try_subset(bi, j, sub_i, sub_c, s)
                if err is not None:
                    _bisect(bi, j, sub_i, sub_c)
                    continue
                ok = (_slab_rows_ok(m) if finite_check
                      else np.ones(len(sub_i), bool))
                _accept_rows(sub_i, sub_c, m, host, ok)
                if not ok.all():
                    _bisect(bi, j,
                            [i for b, i in enumerate(sub_i) if not ok[b]],
                            [c for b, c in enumerate(sub_c) if not ok[b]])

        def _recover_chunk(bi, j, idxs, chunk, first_error):
            """Chunk-level failure path: whole-chunk retries with backoff;
            retries exhausted (or surviving non-finite rows) bisect down
            to the scenarios responsible. Never raises — the campaign
            completes with quarantined rows instead of dying."""
            nonlocal n_recovered
            n_recovered += 1
            m, host, err, _ = _try_subset(bi, j, idxs, chunk,
                                          j % n_streams)
            if err is not None:
                _bisect(bi, j, idxs, chunk)
            else:
                ok = (_slab_rows_ok(m) if finite_check
                      else np.ones(len(idxs), bool))
                _accept_rows(idxs, chunk, m, host, ok)
                if not ok.all():
                    _bisect(bi, j,
                            [i for b, i in enumerate(idxs) if not ok[b]],
                            [c for b, c in enumerate(chunk) if not ok[b]])
            _chunk_complete(j, idxs)

        # manual executor lifecycle (not a `with` block): the transfer
        # watchdog may abandon a wedged executor mid-run and install a
        # fresh one, and the finally-teardown must be able to cancel
        # whatever executor is current at failure time
        ex_holder = [ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="h2d")]
        status = "failed"
        error_repr = None
        try:
            for j, (bi, idxs) in enumerate(jobs):
                if j in done_jobs:
                    continue  # restored bitwise from the checkpoint
                s = j % n_streams
                _fire("abort", j)
                # --- compute: if the previous chunk's bytes already
                # landed, put it to work BEFORE packing the next chunk so
                # its program runs under the whole stage interval ---
                if pending[s] is not None and pending[s][4].done():
                    _dispatch(s)
                shape = plan[bi][1]
                rows = cap_rows[bi]
                shape_t = dataclasses.astuple(shape)
                chunk = [sims[i] for i in idxs]
                # --- stage chunk j into this stream's rotating slot ---
                t0 = time.perf_counter()
                try:
                    _fire("pack", j)
                    # THREE slot phases, one per pipeline stage:
                    # device_put on CPU zero-copy ALIASES any
                    # 64-byte-aligned numpy buffer (measured; whether a
                    # given np.empty lands aligned is allocator luck), so
                    # a slot may only be refilled once its previous
                    # occupant's *execution* has been collected — not
                    # merely once its transfer resolved. The pipeline lags
                    # staging by at most two chunks (one pending transfer
                    # plus one uncollected dispatch: the forced dispatch
                    # before every submit collects down to a single
                    # in-flight chunk), so phase c%3 — last filled for
                    # chunk c-3, collected during chunk c-2's dispatch —
                    # is guaranteed idle. Slots of any OTHER shape on this
                    # stream are dropped (an in-progress transfer keeps
                    # the numpy alive via its own reference; dropping the
                    # dict entry never mutates)
                    for k in [k for k in self._campaign_bufs
                              if k[2] == s and k[:2] != (shape_t, rows)]:
                        del self._campaign_bufs[k]
                    bufs = self._campaign_bufs.setdefault(
                        (shape_t, rows, s, staged_n[s] % 3), {})
                    leaves = self._fill_bucket(bufs, chunk, shape, rows)
                    stacked = CompiledSim(tuples_per_mb=1.0,
                                          n_apps=shape.n_apps, **leaves)
                    if x_fixed is None:
                        xf = None
                    else:
                        xf = np.zeros((rows, shape.n_flows), np.float32)
                        for b, i in enumerate(idxs):
                            xf[b, :len(x_fixed[i])] = np.asarray(
                                x_fixed[i], np.float32)
                    enf = np.zeros(rows, bool)
                    for b, sim in enumerate(chunk):
                        enf[b] = sim.is_dynamic
                except Exception as e:  # noqa: BLE001 — route to recovery
                    # pack failed before the slot advanced: nothing was
                    # submitted, the phase counter stays put, and the
                    # chunk re-runs synchronously on scratch buffers
                    stage_s += time.perf_counter() - t0
                    _recover_chunk(bi, j, idxs, chunk, e)
                    continue
                staged_n[s] += 1
                t1 = time.perf_counter()
                stage_s += t1 - t0
                # overlap bookkeeping: staging is *hidden* when compute is
                # in flight somewhere; it is *hideable* unless the pipeline
                # had nothing it could possibly run yet (the very first
                # chunk's stage — and nothing else — precedes all work)
                if inflight_total:
                    hidden_stage_s += t1 - t0
                if inflight_total or any(p is not None for p in pending):
                    hideable_stage_s += t1 - t0
                live = sum(b.nbytes
                           for slot in self._campaign_bufs.values()
                           for b in slot.values())
                peak_bytes = max(peak_bytes, live)
                peak_rows = max(peak_rows,
                                sum(k[1] for k in self._campaign_bufs))
                # --- transfer: single-entry prefetch slot per stream —
                # drain it (dispatching its chunk) before submitting the
                # next copy, then hand chunk j to the worker ---
                if pending[s] is not None:
                    _dispatch(s)
                fut = ex_holder[0].submit(_h2d, (stacked, xf, enf),
                                          stream_sh[s], j)
                pending[s] = (bi, j, idxs, chunk, fut)
            # --- pipeline drain: flush prefetched chunks, then collect ---
            for s in range(n_streams):
                if pending[s] is not None:
                    _dispatch(s)
            for s in range(n_streams):
                while inflight[s]:
                    _collect_oldest(s)
            status = "ok"
        except BaseException as e:
            error_repr = repr(e)
            raise
        finally:
            # teardown runs on success AND on any failure (including
            # KeyboardInterrupt / injected aborts): cancel in-flight
            # transfers, drop uncollected dispatches, and write
            # failure-aware stats — a dead campaign must never leave the
            # runner replaying the previous run's numbers or holding
            # slots an abandoned transfer still aliases
            for s in range(n_streams):
                if pending[s] is not None:
                    pending[s][4].cancel()
                    pending[s] = None
                inflight[s].clear()
            ex_holder[0].shutdown(wait=(status == "ok"),
                                  cancel_futures=True)
            if status != "ok":
                self._campaign_bufs.clear()
            wall_s = time.perf_counter() - t_wall0
            self.last_stats = {
                "mode": "campaign",
                "status": status,
                "error": error_repr,
                "n_dispatches": n_dispatched,
                "n_chunks": len(jobs),
                "n_chunks_done": chunks_done,
                "n_chunks_resumed": n_resumed,
                "n_retries": n_retries,
                "n_recovered_chunks": n_recovered,
                "n_quarantined": len({f.scenario for f in failures}),
                "checkpoint": ckpt_dir,
                "fingerprint": ckpt_fp,
                "n_buckets": len(plan),
                "n_scenarios": len(sims),
                "n_streams": n_streams,
                "rows": cap_rows,
                "chunk_rows": max(cap_rows),
                "target_chunk_rows": target_rows,
                "auto_chunk": auto_chunk,
                "bucket_shapes": [dataclasses.astuple(s) for _, s in plan],
                "policy": policy,
                "peak_staged_rows": peak_rows,
                "peak_staged_bytes": peak_bytes,
                "stage_s": stage_s,
                "dispatch_s": dispatch_s,
                "transfer_s": transfer_s,
                "transfer_wait_s": transfer_wait_s,
                "block_s": block_s,
                "wall_s": wall_s,
                "overlap_fraction": (hidden_stage_s / hideable_stage_s
                                     if hideable_stage_s > 0 else 1.0),
                "transfer_overlap": (
                    max(0.0, 1.0 - transfer_wait_s / transfer_s)
                    if transfer_s > 0 else 0.0),
                "calibration": dataclasses.asdict(calib),
            }
        return CampaignResult(
            metrics=metrics_all,
            tuples_per_mb=np.asarray([s.tuples_per_mb for s in sims],
                                     np.float32),
            dt=dt,
            policy=policy,
            results=results,  # type: ignore[arg-type]
            failures=failures,
        )

    # ------------------------------------------------------ introspection
    def compile_cache_size(self) -> int:
        """Number of compiled executables held by *this runner's* entry
        points — one per (pack signature, policy, solver, n_ticks,
        upd_every, dt, device count) key. Flat across repeat calls ⇒ the
        warm path recompiled nothing. Per-instance by construction:
        another runner's compilations can't leak into this count."""
        return sum(fn._cache_size() for fn in self._executables.values())


_DEFAULT_RUNNER: FleetRunner | None = None


def _default_runner() -> FleetRunner:
    global _DEFAULT_RUNNER
    if _DEFAULT_RUNNER is None:
        _DEFAULT_RUNNER = FleetRunner()
    return _DEFAULT_RUNNER


def simulate_many(
    sims: Sequence[CompiledSim],
    policy: str = "tcp",
    seconds: float = 600.0,
    dt: float = 0.5,
    upd_every: int | None = None,
    x_fixed: Sequence[np.ndarray] | None = None,
    alpha: float = 0.5,
    n_groups: int = 8,
    qcap: float = 8.0,
    solver: str = "sort",
    shard: bool = True,
) -> list[SimResult]:
    """Thin wrapper over a module-level :class:`FleetRunner` (PR 1 API):
    packed single-dispatch batched execution; see
    :meth:`FleetRunner.run`."""
    return _default_runner().run(
        sims, policy=policy, seconds=seconds, dt=dt, upd_every=upd_every,
        x_fixed=x_fixed, alpha=alpha, n_groups=n_groups, qcap=qcap,
        solver=solver, shard=shard)
