"""Batched multi-scenario simulation: run a *fleet* of independent
simulations as one jitted ``jax.vmap``-over-``lax.scan`` program.

The paper validates Alg. 1 on one 10-workstation topology (§VI); every
follow-up question — capacity sweeps, placement studies, link failures,
random-DAG robustness — is "run the same simulator on N variants". Doing
that as a python loop costs N separate XLA compilations (every scenario has
its own [F, L, I] shape) plus N dispatch streams. Instead we:

  1. ``pad_sim``  — zero-pad one :class:`CompiledSim` to a common
     ``[F_max, L_max, I_max, P_max, A_max]`` shape. Padding is *neutral by
     construction*: padded flows have no routing-matrix entries, no
     producers, and zero queues, so they move no bytes; padded links carry
     huge capacity and INTERNAL kind, so no solver ever binds on them;
     padded instances generate/consume nothing; padded path rows are all
     zero (the latency estimate is a pre-normalized sum, see
     ``compile_sim``). A padded sim's trajectory equals the unpadded one's
     on the real entries.
  2. ``stack_sims`` — stack the padded pytrees into one batched
     :class:`CompiledSim` (leading axis = scenario).
  3. ``simulate_many`` — ``jax.vmap`` the existing scan-based ``_run`` over
     the stacked batch: ONE compile, one fused program for the whole fleet,
     then slice each scenario's outputs back to its true shapes.

Exact parity with per-scenario ``simulate`` holds for the "tcp",
"appaware", and "fixed" policies. For "appfair" the priority grouping is a
function of the *number of apps*, so padding ``n_apps`` up to the fleet
maximum can shift quantile-bucket boundaries when scenarios disagree on
app count; batch "appfair" fleets with equal ``n_apps`` for exactness.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.net.topology import LinkKind
from repro.streams.simulator import (
    CompiledSim,
    SimResult,
    _run,
    resolve_upd_every,
    smoke_seconds,
)

# padded links must never constrain any solver: effectively infinite pipes
_PAD_CAP = 1e9


@dataclasses.dataclass(frozen=True)
class FleetShape:
    """Common padded shape of a stacked fleet."""

    n_flows: int
    n_links: int
    n_insts: int
    n_paths: int
    n_apps: int

    @classmethod
    def cover(cls, sims: Sequence[CompiledSim]) -> "FleetShape":
        """Smallest shape covering every sim in the fleet."""
        return cls(
            n_flows=max(s.R.shape[0] for s in sims),
            n_links=max(s.R.shape[1] for s in sims),
            n_insts=max(s.M_in.shape[0] for s in sims),
            n_paths=max(s.paths.shape[0] for s in sims),
            n_apps=max(s.n_apps for s in sims),
        )


# padding/stacking run in numpy: hundreds of tiny jnp.pad dispatches would
# dominate the batched path's wall-clock before XLA ever runs
def _pad1(a, n, value=0.0):
    a = np.asarray(a)
    pad = n - a.shape[0]
    return a if pad <= 0 else np.pad(a, (0, pad), constant_values=value)


def _pad2(a, n0, n1):
    a = np.asarray(a)
    p0, p1 = n0 - a.shape[0], n1 - a.shape[1]
    if p0 <= 0 and p1 <= 0:
        return a
    return np.pad(a, ((0, max(p0, 0)), (0, max(p1, 0))))


def pad_sim(sim: CompiledSim, shape: FleetShape,
            tuples_per_mb: float | None = None) -> CompiledSim:
    """Zero-pad ``sim`` to ``shape`` without changing its dynamics.

    ``tuples_per_mb`` (a *static* pytree field) may be overridden so every
    member of a fleet shares one treedef; callers keep the true value per
    scenario (``simulate_many`` does) for throughput conversion.
    """
    F, L = shape.n_flows, shape.n_links
    I, P, A = shape.n_insts, shape.n_paths, shape.n_apps
    if sim.n_apps > A:
        raise ValueError(f"cannot pad n_apps {sim.n_apps} down to {A}")
    f = False
    return CompiledSim(
        R=_pad2(sim.R, F, L),
        caps=_pad1(sim.caps, L, _PAD_CAP),
        kinds=_pad1(sim.kinds, L, int(LinkKind.INTERNAL)),
        has_links=_pad1(sim.has_links, F, f),
        M_in=_pad2(sim.M_in, I, F),
        w_out=_pad2(sim.w_out, I, F),
        p_in=_pad1(sim.p_in, F),
        proc_rate=_pad1(sim.proc_rate, I),
        selectivity=_pad1(sim.selectivity, I),
        gen_rate=_pad1(sim.gen_rate, I),
        is_join=_pad1(sim.is_join, I, f),
        is_sink=_pad1(sim.is_sink, I, f),
        join_dst=_pad1(sim.join_dst, F, f),
        droppable=_pad1(sim.droppable, F, f),
        dst_of_flow=_pad1(sim.dst_of_flow, F, 0),
        paths=_pad2(sim.paths, P, F),
        tuples_per_mb=(sim.tuples_per_mb if tuples_per_mb is None
                       else float(tuples_per_mb)),
        app_of_flow=_pad1(sim.app_of_flow, F, 0),
        app_of_inst=_pad1(sim.app_of_inst, I, 0),
        n_apps=A,
    )


def stack_sims(
    sims: Sequence[CompiledSim], shape: FleetShape | None = None
) -> tuple[CompiledSim, FleetShape]:
    """Pad every sim to a common shape and stack into one batched pytree
    (every array leaf gains a leading scenario axis)."""
    if not sims:
        raise ValueError("empty fleet")
    shape = FleetShape.cover(sims) if shape is None else shape
    padded = [pad_sim(s, shape, tuples_per_mb=1.0) for s in sims]
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.asarray(np.stack(xs)), *padded)
    return stacked, shape


def _run_fleet(stacked: CompiledSim, policy: str, n_ticks: int, dt: float,
               upd_every: int, x_fixed, alpha: float, n_groups: int,
               qcap: float, solver: str):
    def one(sim, xf):
        return _run(sim, policy, n_ticks, dt, upd_every, x_fixed=xf,
                    alpha=alpha, n_groups=n_groups, qcap=qcap, solver=solver)

    if x_fixed is None:
        return jax.vmap(lambda s: one(s, None))(stacked)
    return jax.vmap(one)(stacked, x_fixed)


def _shard_batch(tree, n_scen: int):
    """Place the stacked batch axis across all local devices (no-op on one
    device). The batch is padded to a device multiple by the caller."""
    devs = jax.devices()
    if len(devs) <= 1 or n_scen % len(devs) != 0:
        return tree
    mesh = Mesh(np.asarray(devs), ("scenarios",))
    sharding = NamedSharding(mesh, PartitionSpec("scenarios"))
    return jax.tree_util.tree_map(
        lambda a: jax.device_put(a, sharding), tree)


def simulate_many(
    sims: Sequence[CompiledSim],
    policy: str = "tcp",
    seconds: float = 600.0,
    dt: float = 0.5,
    upd_every: int | None = None,
    x_fixed: Sequence[np.ndarray] | None = None,
    alpha: float = 0.5,
    n_groups: int = 8,
    qcap: float = 8.0,
    solver: str = "sort",
    shard: bool = True,
) -> list[SimResult]:
    """Run the whole fleet as one vmapped program; one :class:`SimResult`
    per scenario, each sliced back to that scenario's true [L]/[A] shapes —
    element-wise equal to ``simulate(sims[b], ...)`` (see module docstring
    for the "appfair" caveat).

    With >1 local device (e.g. ``--xla_force_host_platform_device_count``
    on CPU, or a TPU slice) and ``shard=True``, the scenario axis is
    sharded across devices: the batch is padded with replicas of the last
    scenario up to a device multiple and the extras are dropped on return.
    """
    if not sims:
        raise ValueError("empty fleet")
    if policy == "appfair" and len({s.n_apps for s in sims}) > 1:
        # padding n_apps up to the fleet max shifts the priority-grouping
        # quantile buckets (see module docstring): parity would silently break
        raise ValueError(
            "appfair fleets must share one n_apps; batch per app count")
    n_dev = len(jax.devices()) if shard else 1
    pad_b = (-len(sims)) % n_dev if n_dev > 1 else 0
    run_sims = list(sims) + [sims[-1]] * pad_b
    stacked, shape = stack_sims(run_sims)
    n_ticks = int(round(smoke_seconds(seconds) / dt))
    upd_every = resolve_upd_every(policy, dt, upd_every)
    xf = None
    if x_fixed is not None:
        if len(x_fixed) != len(sims):
            raise ValueError("x_fixed must give one rate vector per scenario")
        xf = jnp.stack([
            _pad1(jnp.asarray(x, jnp.float32), shape.n_flows)
            for x in list(x_fixed) + [x_fixed[-1]] * pad_b
        ])
    if shard:
        stacked = _shard_batch(stacked, len(run_sims))
        if xf is not None:
            xf = _shard_batch(xf, len(run_sims))
    sink, sink_app, lat, load = _run_fleet(
        stacked, policy, n_ticks, dt, upd_every, xf, alpha, n_groups, qcap,
        solver,
    )
    sink, sink_app = np.asarray(sink), np.asarray(sink_app)
    lat, load = np.asarray(lat), np.asarray(load)
    out = []
    for b, sim in enumerate(sims):
        L, A = sim.caps.shape[0], sim.n_apps
        out.append(SimResult(
            sink_mb=sink[b],
            sink_mb_app=sink_app[b][:, :A],
            latency=lat[b],
            link_load=load[b][:, :L],
            caps=np.asarray(sim.caps),
            kinds=np.asarray(sim.kinds),
            tuples_per_mb=sim.tuples_per_mb,
            dt=dt,
        ))
    return out
