"""Batched multi-scenario simulation: run a *fleet* of independent
simulations as shape-bucketed, jitted ``jax.vmap``-over-``lax.scan``
programs behind a persistent :class:`FleetRunner`.

The paper validates Alg. 1 on one 10-workstation topology (§VI); every
follow-up question — capacity sweeps, placement studies, link failures,
random-DAG robustness — is "run the same simulator on N variants". Doing
that as a python loop costs N separate XLA compilations (every scenario has
its own [F, L, I] shape) plus N dispatch streams. Padding everything to the
*global* max shape fixes the compile count but makes the post-compile path
padding-bound when shapes are heterogeneous. The runner splits the
difference:

  1. **Shape bucketing** — scenarios are grouped into at most
     ``max_buckets`` buckets by greedy agglomerative merging under a
     padded-FLOP waste model (:func:`_flop_cost`): starting from one bucket
     per distinct true shape, the pair whose merge adds the least padded
     compute is merged until the budget is met. Each bucket pads only to
     *its own* cover shape, so a fleet of mostly-small scenarios no longer
     pays the largest member's shape on every tick.
  2. **Compile caching** — each bucket dispatches through one module-level
     jitted entry point; XLA caches one executable per
     ``(bucket shape, policy, solver, n_ticks, upd_every, dt)`` key, so
     repeat studies (parameter sweeps re-using the same fleet) reuse
     executables across calls. :meth:`FleetRunner.compile_cache_size`
     exposes the cache occupancy for no-recompile assertions.
  3. **Staging buffers** — per ``(bucket shape, batch)`` the runner keeps
     preallocated numpy buffers; repeat calls re-stack scenarios by slice
     assignment into the existing buffers instead of re-padding every leaf
     through fresh allocations.
  4. **Donation** — the stacked device buffers are donated to the jitted
     call (``donate_argnums``), letting XLA reuse their memory for the
     trajectory outputs on the warm path; the numpy staging copies remain
     the host-side source of truth.

Padding within a bucket is *neutral by construction*: padded flows have no
routing-matrix entries, no producers, and zero queues, so they move no
bytes; padded links carry huge capacity and INTERNAL kind, so no solver
ever binds on them; padded instances generate/consume nothing; padded path
rows are all zero (the latency estimate is a pre-normalized sum, see
``compile_sim``); padded capacity-schedule components are exact no-ops
(zero-amplitude sinusoids, never-active events), so fleets mixing
scheduled and static scenarios batch together without recompiling. A
padded sim's trajectory equals the unpadded one's on the real entries —
with one carve-out: a static sim padded into a *scheduled* bucket takes
the per-tick capacity-enforcement path, which only coincides with its
standalone trajectory when the rate vector is link-feasible. The solver
policies guarantee that; brute-force ``x_fixed`` studies deliberately
don't, so "fixed" fleets bucket static and scheduled scenarios separately
(``split_sched``).

Exact parity with per-scenario ``simulate`` holds for every policy,
**including "appfair"**: its priority grouping depends on the number of
apps, so the runner buckets appfair fleets by *exact* ``n_apps`` (buckets
already group by shape; the app axis is simply never padded across
scenarios that disagree on app count) instead of restricting fleets to a
single app count.

``pad_sim`` / ``stack_sims`` remain as the one-shot stacking primitives;
``simulate_many`` is a thin wrapper over a module-level runner, so the PR 1
API is unchanged.
"""
from __future__ import annotations

import dataclasses
import warnings
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.net.topology import LinkKind
from repro.streams.simulator import (
    CompiledSim,
    SimResult,
    _run,
    resolve_upd_every,
    smoke_seconds,
)

# padded links must never constrain any solver: effectively infinite pipes
_PAD_CAP = 1e9


@dataclasses.dataclass(frozen=True)
class FleetShape:
    """Common padded shape of a stacked fleet (or of one bucket)."""

    n_flows: int
    n_links: int
    n_insts: int
    n_paths: int
    n_apps: int
    # capacity-schedule axes: sinusoidal components / failure events.
    # Padded sinusoids have zero amplitude, padded events never activate,
    # so static and scheduled scenarios batch together exactly.
    n_sins: int = 0
    n_events: int = 0

    @classmethod
    def cover(cls, sims: Sequence[CompiledSim]) -> "FleetShape":
        """Smallest shape covering every sim in the fleet."""
        return cls(
            n_flows=max(s.R.shape[0] for s in sims),
            n_links=max(s.R.shape[1] for s in sims),
            n_insts=max(s.M_in.shape[0] for s in sims),
            n_paths=max(s.paths.shape[0] for s in sims),
            n_apps=max(s.n_apps for s in sims),
            n_sins=max(s.sin_amp.shape[0] for s in sims),
            n_events=max(s.ev_t0.shape[0] for s in sims),
        )

    def merge(self, other: "FleetShape") -> "FleetShape":
        return FleetShape(*(max(a, b) for a, b in
                            zip(dataclasses.astuple(self),
                                dataclasses.astuple(other))))


def _sim_shape(sim: CompiledSim) -> FleetShape:
    return FleetShape(
        n_flows=sim.R.shape[0], n_links=sim.R.shape[1],
        n_insts=sim.M_in.shape[0], n_paths=sim.paths.shape[0],
        n_apps=sim.n_apps, n_sins=sim.sin_amp.shape[0],
        n_events=sim.ev_t0.shape[0])


def _flop_cost(shape: FleetShape) -> float:
    """Per-tick padded-FLOP proxy: the simulator's [I, F] dataflow matmuls,
    the [F, L] link products, and the allocator's [L, F] batched solve all
    scale with these products (constants drop out of the waste comparison).
    """
    F, L = shape.n_flows, shape.n_links
    return F * L + 2.0 * shape.n_insts * F + shape.n_paths * F


def _has_sched(shape: FleetShape) -> bool:
    return shape.n_sins > 0 or shape.n_events > 0


def _plan_buckets(sims: Sequence[CompiledSim], max_buckets: int,
                  exact_apps: bool,
                  split_sched: bool = False) -> list[tuple[list[int],
                                                           FleetShape]]:
    """Greedy agglomerative bucketing: start from one bucket per distinct
    true shape, repeatedly merge the pair that adds the least padded FLOPs,
    stop at ``max_buckets``. With ``exact_apps`` (the "appfair" policy)
    only buckets with equal ``n_apps`` may merge — the priority grouping is
    a function of the app count, so the app axis is never padded across
    disagreeing scenarios (the bucket count may then exceed the budget by
    necessity: one bucket per app count at minimum). With ``split_sched``
    (the "fixed" policy) static and scheduled scenarios never share a
    bucket: a static sim padded into a scheduled bucket takes the per-tick
    capacity-enforcement path, which only matches its standalone trajectory
    when the rate vector is link-feasible — guaranteed for the solver
    policies but *deliberately violated* by brute-force ``x_fixed``
    studies."""
    by_shape: dict[tuple, list[int]] = {}
    for i, s in enumerate(sims):
        by_shape.setdefault(dataclasses.astuple(_sim_shape(s)), []).append(i)
    buckets = [(idxs, FleetShape(*key)) for key, idxs in by_shape.items()]

    def merge_waste(a, b):
        (ia, sa), (ib, sb) = a, b
        cover = sa.merge(sb)
        return ((len(ia) + len(ib)) * _flop_cost(cover)
                - len(ia) * _flop_cost(sa) - len(ib) * _flop_cost(sb))

    while len(buckets) > max_buckets:
        best = None
        for j in range(len(buckets)):
            for k in range(j + 1, len(buckets)):
                if exact_apps and (buckets[j][1].n_apps
                                   != buckets[k][1].n_apps):
                    continue
                if split_sched and (_has_sched(buckets[j][1])
                                    != _has_sched(buckets[k][1])):
                    continue
                w = merge_waste(buckets[j], buckets[k])
                if best is None or w < best[0]:
                    best = (w, j, k)
        if best is None:  # no feasible merge (exact_apps partitions)
            break
        _, j, k = best
        (ij, sj), (ik, sk) = buckets[j], buckets[k]
        merged = (ij + ik, sj.merge(sk))
        buckets = [b for i, b in enumerate(buckets) if i not in (j, k)]
        buckets.append(merged)
    return buckets


# padding/stacking run in numpy: hundreds of tiny jnp.pad dispatches would
# dominate the batched path's wall-clock before XLA ever runs
def _pad1(a, n, value=0.0):
    a = np.asarray(a)
    pad = n - a.shape[0]
    return a if pad <= 0 else np.pad(a, (0, pad), constant_values=value)


def _pad2(a, n0, n1):
    a = np.asarray(a)
    p0, p1 = n0 - a.shape[0], n1 - a.shape[1]
    if p0 <= 0 and p1 <= 0:
        return a
    return np.pad(a, ((0, max(p0, 0)), (0, max(p1, 0))))


def pad_sim(sim: CompiledSim, shape: FleetShape,
            tuples_per_mb: float | None = None) -> CompiledSim:
    """Zero-pad ``sim`` to ``shape`` without changing its dynamics.

    ``tuples_per_mb`` (a *static* pytree field) may be overridden so every
    member of a fleet shares one treedef; callers keep the true value per
    scenario (``FleetRunner`` does) for throughput conversion.
    """
    F, L = shape.n_flows, shape.n_links
    I, P, A = shape.n_insts, shape.n_paths, shape.n_apps
    S, E = shape.n_sins, shape.n_events
    if sim.n_apps > A:
        raise ValueError(f"cannot pad n_apps {sim.n_apps} down to {A}")
    f = False
    return CompiledSim(
        R=_pad2(sim.R, F, L),
        caps=_pad1(sim.caps, L, _PAD_CAP),
        kinds=_pad1(sim.kinds, L, int(LinkKind.INTERNAL)),
        has_links=_pad1(sim.has_links, F, f),
        M_in=_pad2(sim.M_in, I, F),
        w_out=_pad2(sim.w_out, I, F),
        p_in=_pad1(sim.p_in, F),
        proc_rate=_pad1(sim.proc_rate, I),
        selectivity=_pad1(sim.selectivity, I),
        gen_rate=_pad1(sim.gen_rate, I),
        is_join=_pad1(sim.is_join, I, f),
        is_sink=_pad1(sim.is_sink, I, f),
        join_dst=_pad1(sim.join_dst, F, f),
        droppable=_pad1(sim.droppable, F, f),
        dst_of_flow=_pad1(sim.dst_of_flow, F, 0),
        src_of_flow=_pad1(sim.src_of_flow, F, 0),
        w_of_flow=_pad1(sim.w_of_flow, F),
        paths=_pad2(sim.paths, P, F),
        tuples_per_mb=(sim.tuples_per_mb if tuples_per_mb is None
                       else float(tuples_per_mb)),
        app_of_flow=_pad1(sim.app_of_flow, F, 0),
        app_of_inst=_pad1(sim.app_of_inst, I, 0),
        n_apps=A,
        sin_amp=_pad2(sim.sin_amp, S, L),
        sin_omega=_pad2(sim.sin_omega, S, L),
        sin_phase=_pad2(sim.sin_phase, S, L),
        ev_t0=_pad1(sim.ev_t0, E, np.inf),
        ev_t1=_pad1(sim.ev_t1, E, np.inf),
        ev_link=_pad1(sim.ev_link, E, 0),
        ev_scale=_pad1(sim.ev_scale, E, 1.0),
    )


def stack_sims(
    sims: Sequence[CompiledSim], shape: FleetShape | None = None
) -> tuple[CompiledSim, FleetShape]:
    """Pad every sim to a common shape and stack into one batched pytree
    (every array leaf gains a leading scenario axis)."""
    if not sims:
        raise ValueError("empty fleet")
    shape = FleetShape.cover(sims) if shape is None else shape
    padded = [pad_sim(s, shape, tuples_per_mb=1.0) for s in sims]
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.asarray(np.stack(xs)), *padded)
    return stacked, shape


# field -> (padded-dim axes, pad value); dims keyed into {F, L, I, P}
_FIELD_SPECS: dict[str, tuple[tuple[str, ...], float]] = {
    "R": (("F", "L"), 0.0),
    "caps": (("L",), _PAD_CAP),
    "kinds": (("L",), int(LinkKind.INTERNAL)),
    "has_links": (("F",), False),
    "M_in": (("I", "F"), 0.0),
    "w_out": (("I", "F"), 0.0),
    "p_in": (("F",), 0.0),
    "proc_rate": (("I",), 0.0),
    "selectivity": (("I",), 0.0),
    "gen_rate": (("I",), 0.0),
    "is_join": (("I",), False),
    "is_sink": (("I",), False),
    "join_dst": (("F",), False),
    "droppable": (("F",), False),
    "dst_of_flow": (("F",), 0),
    "src_of_flow": (("F",), 0),
    "w_of_flow": (("F",), 0.0),
    "paths": (("P", "F"), 0.0),
    "app_of_flow": (("F",), 0),
    "app_of_inst": (("I",), 0),
    "sin_amp": (("S", "L"), 0.0),
    "sin_omega": (("S", "L"), 0.0),
    "sin_phase": (("S", "L"), 0.0),
    "ev_t0": (("E",), np.inf),
    "ev_t1": (("E",), np.inf),
    "ev_link": (("E",), 0),
    "ev_scale": (("E",), 1.0),
}


def _run_fleet_impl(stacked, xf, qcap, *, policy, n_ticks, dt, upd_every,
                    alpha, n_groups, solver):
    def one(sim, x):
        return _run(sim, policy, n_ticks, dt, upd_every, x_fixed=x,
                    alpha=alpha, n_groups=n_groups, qcap=qcap, solver=solver)

    if xf is None:
        return jax.vmap(lambda s: one(s, None))(stacked)
    return jax.vmap(one)(stacked, xf)


# one jitted executable per (device count, policy, solver, n_ticks,
# upd_every, dt, alpha, n_groups) key; XLA's jit cache then adds the bucket
# shape axis. Kept in a dict (not lru_cache) so cache occupancy is
# introspectable for no-recompile assertions.
_EXECUTABLES: dict[tuple, "jax.stages.Wrapped"] = {}


def _fleet_executable(n_shards: int, policy: str, n_ticks: int, dt: float,
                      upd_every: int, alpha: float, n_groups: int,
                      solver: str):
    """Build (and cache) the jitted fleet entry point.

    With ``n_shards`` > 1 the batch axis is split across local devices as
    **plain SPMD sharding** (``jit`` + ``in_shardings`` on the scenario
    axis). Earlier revisions wrapped the body in ``shard_map`` so the
    data-dependent ``while_loop``s inside the policies (the max-min
    progressive filling) kept device-local trip counts — a plain
    SPMD-sharded batch axis paid a cross-device all-reduce on every loop
    predicate. The fused fixed-trip max-min solver
    (:func:`repro.core.tcp.maxmin_fused`) removed the last data-dependent
    control flow from every policy, so the partitioner now sees a purely
    batch-parallel program and the ``shard_map`` staging (and its
    ``check_rep=False`` escape hatch) is unnecessary. The stacked batch
    (and x_fixed) buffers are donated on dispatch: XLA may reuse their
    memory for the trajectory outputs on the warm path; the runner's numpy
    staging buffers remain the host-side copy and are re-pushed on the
    next call.
    """
    key = (n_shards, policy, n_ticks, dt, upd_every, alpha, n_groups, solver)
    fn = _EXECUTABLES.get(key)
    if fn is not None:
        return fn

    def impl(stacked, xf, qcap):
        return _run_fleet_impl(
            stacked, xf, qcap, policy=policy, n_ticks=n_ticks, dt=dt,
            upd_every=upd_every, alpha=alpha, n_groups=n_groups,
            solver=solver)

    if n_shards > 1:
        mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("scenarios",))
        batch = NamedSharding(mesh, PartitionSpec("scenarios"))
        rep = NamedSharding(mesh, PartitionSpec())
        fn = jax.jit(impl, in_shardings=(batch, batch, rep),
                     donate_argnums=(0, 1))
    else:
        fn = jax.jit(impl, donate_argnums=(0, 1))
    _EXECUTABLES[key] = fn
    return fn


class FleetRunner:
    """Persistent bucketed fleet executor (see module docstring).

    One runner amortizes three caches across calls: the XLA executable per
    ``(bucket shape, policy, solver, n_ticks, upd_every, dt)`` key (held by
    the module-level jitted entry point), the numpy staging buffers per
    ``(bucket shape, batch size)``, and the bucket plan per fleet shape
    multiset. ``simulate_many`` routes through one module-level instance.
    """

    # staging entries kept before the oldest are evicted: each holds one
    # [B, F, L]-scale set of numpy buffers, so an unbounded cache would grow
    # for the life of the process across a many-shaped sweep
    MAX_STAGED = 32

    def __init__(self, max_buckets: int = 4):
        self.max_buckets = int(max_buckets)
        self._staging: dict[tuple, dict[str, np.ndarray]] = {}
        self._stacked: dict[tuple, CompiledSim] = {}
        self._filled: dict[tuple, list] = {}  # bucket key -> sim weakrefs
        self._plan_cache: dict[tuple, list[tuple[list[int], FleetShape]]] = {}

    # ---------------------------------------------------------- planning
    def plan(self, sims: Sequence[CompiledSim], exact_apps: bool = False,
             split_sched: bool = False) -> list[tuple[list[int], FleetShape]]:
        """Bucket assignment for a fleet: list of (scenario indices, padded
        bucket shape). Cached per shape multiset."""
        key = (tuple(dataclasses.astuple(_sim_shape(s)) for s in sims),
               exact_apps, split_sched, self.max_buckets)
        plan = self._plan_cache.get(key)
        if plan is None:
            plan = _plan_buckets(sims, self.max_buckets, exact_apps,
                                 split_sched)
            self._plan_cache[key] = plan
        return plan

    # ----------------------------------------------------------- staging
    def _stack_bucket(self, sims: list[CompiledSim], shape: FleetShape,
                      idxs: list[int]) -> CompiledSim:
        """Stack a bucket into preallocated numpy staging buffers (reset +
        slice-assign; no per-sim np.pad allocations on repeat calls). When
        the bucket holds the *same scenario objects* as the previous call
        (the steady state of a repeat study) the filled buffers are reused
        outright — the warm path re-stacks nothing. The key includes the
        bucket's member indices: two buckets of one fleet can share a
        padded shape and batch size, and a shape-only key would make them
        overwrite each other's staging every call (silently losing the
        warm-path reuse for both)."""
        B = len(sims)
        key = (dataclasses.astuple(shape), tuple(idxs))
        refs = self._filled.get(key)
        if refs is not None and len(refs) == B and all(
                r() is s for r, s in zip(refs, sims)):
            # LRU touch: move the hit key to the back so steady repeat
            # studies never lose their staging to a sweep's churn
            self._staging[key] = self._staging.pop(key)
            return self._stacked[key]
        # bounded cache: drop the oldest staged buckets (and any whose sims
        # were garbage-collected) before staging a new one
        dead = [k for k, rs in self._filled.items()
                if any(r() is None for r in rs)]
        evict = dead + [k for k in self._staging
                        if k not in dead][:max(
                            0, len(self._staging) - len(dead)
                            - self.MAX_STAGED + 1)]
        for k in evict:
            if k != key:
                self._staging.pop(k, None)
                self._stacked.pop(k, None)
                self._filled.pop(k, None)
        bufs = self._staging.setdefault(key, {})
        dims = {"F": shape.n_flows, "L": shape.n_links,
                "I": shape.n_insts, "P": shape.n_paths,
                "S": shape.n_sins, "E": shape.n_events}
        leaves = {}
        for field, (axes, pad) in _FIELD_SPECS.items():
            first = np.asarray(getattr(sims[0], field))
            full = (B,) + tuple(dims[a] for a in axes)
            buf = bufs.get(field)
            if buf is None or buf.shape != full or buf.dtype != first.dtype:
                buf = np.empty(full, first.dtype)
                bufs[field] = buf
            buf.fill(pad)
            for b, s in enumerate(sims):
                a = np.asarray(getattr(s, field))
                buf[(b, *map(lambda n: slice(0, n), a.shape))] = a
            leaves[field] = buf
        stacked = CompiledSim(tuples_per_mb=1.0, n_apps=shape.n_apps,
                              **leaves)
        self._stacked[key] = stacked
        self._filled[key] = [weakref.ref(s) for s in sims]
        return stacked

    # ------------------------------------------------------------ running
    def run(
        self,
        sims: Sequence[CompiledSim],
        policy: str = "tcp",
        seconds: float = 600.0,
        dt: float = 0.5,
        upd_every: int | None = None,
        x_fixed: Sequence[np.ndarray] | None = None,
        alpha: float = 0.5,
        n_groups: int = 8,
        qcap: float = 8.0,
        solver: str = "sort",
        shard: bool = True,
    ) -> list[SimResult]:
        """Run the whole fleet bucket-by-bucket; one :class:`SimResult` per
        scenario (input order), each sliced back to its true [L]/[A]
        shapes — element-wise equal to ``simulate(sims[b], ...)`` for every
        policy (appfair buckets by exact app count).

        With >1 local device (e.g. ``--xla_force_host_platform_device_count``
        on CPU, or a TPU slice) and ``shard=True``, each bucket's scenario
        axis is sharded across devices: the bucket is padded with replicas
        of its last scenario up to a device multiple and the extras are
        dropped on return.
        """
        if not sims:
            raise ValueError("empty fleet")
        sims = list(sims)
        if x_fixed is not None and len(x_fixed) != len(sims):
            raise ValueError("x_fixed must give one rate vector per scenario")
        n_ticks = int(round(smoke_seconds(seconds) / dt))
        upd_every = resolve_upd_every(policy, dt, upd_every)
        n_dev = len(jax.devices()) if shard else 1

        # phase 1: stage + dispatch every bucket (jax dispatch is async, so
        # bucket k+1's host staging/transfer overlaps bucket k's compute)
        pending = []
        for idxs, shape in self.plan(sims,
                                     exact_apps=(policy == "appfair"),
                                     split_sched=(policy == "fixed")):
            pad_b = (-len(idxs)) % n_dev if n_dev > 1 else 0
            run_idxs = idxs + [idxs[-1]] * pad_b
            n_shards = n_dev if (n_dev > 1 and len(run_idxs) % n_dev == 0
                                 ) else 1
            stacked = self._stack_bucket([sims[i] for i in run_idxs], shape,
                                         run_idxs)
            xf = None
            if x_fixed is not None:
                xf = np.stack([
                    _pad1(np.asarray(x_fixed[i], np.float32), shape.n_flows)
                    for i in run_idxs])
            fn = _fleet_executable(n_shards, policy, n_ticks, dt, upd_every,
                                   alpha, n_groups, solver)
            with warnings.catch_warnings():
                # donation is best-effort: int/bool structure leaves can't
                # back the float trajectory outputs and XLA says so per call
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                ys = fn(stacked, xf, jnp.float32(qcap))
            pending.append((idxs, ys))

        # phase 2: collect (first np.asarray per bucket blocks on its result)
        out: list[SimResult | None] = [None] * len(sims)
        for idxs, (sink, sink_app, lat, load, caps_sched) in pending:
            sink, sink_app = np.asarray(sink), np.asarray(sink_app)
            lat, load = np.asarray(lat), np.asarray(load)
            caps_sched = np.asarray(caps_sched)
            for b, i in enumerate(idxs):
                sim = sims[i]
                L, A = sim.caps.shape[0], sim.n_apps
                out[i] = SimResult(
                    sink_mb=sink[b],
                    sink_mb_app=sink_app[b][:, :A],
                    latency=lat[b],
                    link_load=load[b][:, :L],
                    caps=np.asarray(sim.caps),
                    kinds=np.asarray(sim.kinds),
                    tuples_per_mb=sim.tuples_per_mb,
                    dt=dt,
                    caps_t=caps_sched[b][:, :L] if sim.is_dynamic else None,
                )
        return out  # type: ignore[return-value]

    # ------------------------------------------------------ introspection
    @staticmethod
    def compile_cache_size() -> int:
        """Number of compiled executables held by the fleet entry points —
        one per (bucket shape, policy, solver, n_ticks, upd_every, dt,
        device count) key. Flat across repeat calls ⇒ the warm path
        recompiled nothing."""
        return sum(fn._cache_size() for fn in _EXECUTABLES.values())


_DEFAULT_RUNNER: FleetRunner | None = None


def _default_runner() -> FleetRunner:
    global _DEFAULT_RUNNER
    if _DEFAULT_RUNNER is None:
        _DEFAULT_RUNNER = FleetRunner()
    return _DEFAULT_RUNNER


def simulate_many(
    sims: Sequence[CompiledSim],
    policy: str = "tcp",
    seconds: float = 600.0,
    dt: float = 0.5,
    upd_every: int | None = None,
    x_fixed: Sequence[np.ndarray] | None = None,
    alpha: float = 0.5,
    n_groups: int = 8,
    qcap: float = 8.0,
    solver: str = "sort",
    shard: bool = True,
) -> list[SimResult]:
    """Thin wrapper over a module-level :class:`FleetRunner` (PR 1 API):
    bucketed, compile-cached batched execution; see
    :meth:`FleetRunner.run`."""
    return _default_runner().run(
        sims, policy=policy, seconds=seconds, dt=dt, upd_every=upd_every,
        x_fixed=x_fixed, alpha=alpha, n_groups=n_groups, qcap=qcap,
        solver=solver, shard=shard)
