"""TCP baseline: per-flow max-min fair *rate* allocation (paper §VI-A.3).

The paper's baseline is the default transport of Storm/Heron/Flink — TCP
congestion control, which (idealized) converges to max-min fair rates among
flows sharing bottleneck links. We implement exact max-min via progressive
filling on the routing matrix: repeatedly find the tightest link, freeze its
flows at the fair share, remove the link, repeat. Runs in ≤ L iterations;
implemented with `lax.fori_loop` so it jits and batches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_EPS = 1e-9
_INF = jnp.inf


@functools.partial(jax.jit, static_argnames=())
def maxmin_rates(R: jnp.ndarray, capacity: jnp.ndarray,
                 active: jnp.ndarray | None = None) -> jnp.ndarray:
    """Exact max-min fair rates.

    R: [F, L] binary routing; capacity: [L]; active: [F] mask (default all).
    Flows traversing no link get rate +inf (caller clamps to demand).
    """
    F, L = R.shape
    if active is None:
        active = jnp.ones((F,), R.dtype)
    active = active.astype(R.dtype)
    on_net = (jnp.sum(R, axis=1) > 0) & (active > 0)

    def body(_, carry):
        x, frozen, link_done = carry
        unfrozen = (~frozen) & on_net
        n_l = jnp.sum(R * unfrozen[:, None].astype(R.dtype), axis=0)      # [L]
        used = jnp.sum(R * (x * frozen.astype(R.dtype))[:, None], axis=0)  # [L]
        resid = jnp.maximum(capacity - used, 0.0)
        fair = jnp.where((n_l > 0) & (~link_done), resid / jnp.maximum(n_l, 1.0), _INF)
        l_star = jnp.argmin(fair)
        share = fair[l_star]
        any_left = jnp.isfinite(share)
        hit = (R[:, l_star] > 0) & unfrozen & any_left
        x = jnp.where(hit, share, x)
        frozen = frozen | hit
        # one-hot instead of .at[l_star].set: batched scatters compile
        # poorly on CPU when this whole solve is vmapped (fleet engine)
        link_done = link_done | ((jnp.arange(L) == l_star) & any_left)
        return x, frozen, link_done

    x0 = jnp.zeros((F,), R.dtype)
    frozen0 = jnp.zeros((F,), bool)
    done0 = jnp.zeros((L,), bool)
    x, frozen, _ = jax.lax.fori_loop(0, L, body, (x0, frozen0, done0))
    # flows not on any congested link (or off-net): unconstrained
    x = jnp.where(on_net & ~frozen, _INF, x)
    x = jnp.where(on_net, x, jnp.where(active > 0, _INF, 0.0))
    return x


def demand_limited_maxmin(R, capacity, demand, iters: int = 4):
    """Max-min with per-flow demand caps (flows never take more than they can
    send). Iterative: clamp to demand, re-run max-min on residual capacity for
    still-hungry flows — converges quickly for our scenarios."""
    F = R.shape[0]
    x = jnp.zeros((F,), R.dtype)
    satisfied = jnp.zeros((F,), bool)

    def body(_, carry):
        x, satisfied = carry
        used = jnp.sum(R * x[:, None] * satisfied[:, None].astype(R.dtype), axis=0)
        resid = jnp.maximum(capacity - used, 0.0)
        mm = maxmin_rates(R, resid, (~satisfied).astype(R.dtype))
        newly = (~satisfied) & (mm >= demand)
        x = jnp.where(newly, demand, jnp.where(~satisfied, jnp.minimum(mm, demand), x))
        satisfied = satisfied | newly
        return x, satisfied

    x, _ = jax.lax.fori_loop(0, iters, body, (x, satisfied))
    return jnp.where(jnp.isfinite(x), x, demand)
