"""TCP baseline: per-flow max-min fair *rate* allocation (paper §VI-A.3).

The paper's baseline is the default transport of Storm/Heron/Flink — TCP
congestion control, which (idealized) converges to max-min fair rates among
flows sharing bottleneck links. We implement exact max-min via progressive
filling on the routing matrix: per round, find the tightest fair share and
freeze every link (and its flows) at that water level, repeat. Implemented
with `lax.while_loop` so it jits and batches; the trip count tracks the
number of distinct bottleneck *levels* (typically a handful), not the link
count — padded links in the fleet engine never bind and cost nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_EPS = 1e-9
_INF = jnp.inf


@functools.partial(jax.jit, static_argnames=())
def maxmin_rates(R: jnp.ndarray, capacity: jnp.ndarray,
                 active: jnp.ndarray | None = None) -> jnp.ndarray:
    """Exact max-min fair rates.

    R: [F, L] binary routing; capacity: [L]; active: [F] mask (default all).
    Flows traversing no link get rate +inf (caller clamps to demand).
    """
    F, L = R.shape
    if active is None:
        active = jnp.ones((F,), R.dtype)
    active = active.astype(R.dtype)
    on_net = (jnp.sum(R, axis=1) > 0) & (active > 0)

    def body(carry):
        x, frozen, link_done, _ = carry
        unfrozen = (~frozen) & on_net
        n_l = jnp.sum(R * unfrozen[:, None].astype(R.dtype), axis=0)      # [L]
        used = jnp.sum(R * (x * frozen.astype(R.dtype))[:, None], axis=0)  # [L]
        resid = jnp.maximum(capacity - used, 0.0)
        fair = jnp.where((n_l > 0) & (~link_done), resid / jnp.maximum(n_l, 1.0), _INF)
        share = jnp.min(fair)
        any_left = jnp.isfinite(share)
        # freeze EVERY link attaining the current water level at once
        # (classic progressive filling fills all tightest links together:
        # their unfrozen flows get the same share either way, so one round
        # per *bottleneck level* instead of one per bottleneck link)
        tight = (fair <= share) & any_left                           # [L]
        hit = jnp.any(R * tight[None, :].astype(R.dtype), axis=1) & unfrozen
        x = jnp.where(hit, share, x)
        frozen = frozen | hit
        link_done = link_done | tight
        return x, frozen, link_done, any_left

    x0 = jnp.zeros((F,), R.dtype)
    frozen0 = jnp.zeros((F,), bool)
    done0 = jnp.zeros((L,), bool)
    # while-loop instead of a fixed L-trip fori: each round freezes one
    # water level, and the loop exits as soon as no link has unfrozen
    # flows left — so the trip count tracks the scenario's *real* bottleneck
    # structure (#levels), not the (possibly padded — fleet engine) link
    # count. The body is idempotent once nothing binds.
    x, frozen, _, _ = jax.lax.while_loop(
        lambda c: c[3], body, (x0, frozen0, done0, jnp.array(True)))
    # flows not on any congested link (or off-net): unconstrained
    x = jnp.where(on_net & ~frozen, _INF, x)
    x = jnp.where(on_net, x, jnp.where(active > 0, _INF, 0.0))
    return x


def demand_limited_maxmin(R, capacity, demand, iters: int = 4):
    """Max-min with per-flow demand caps (flows never take more than they can
    send). Iterative: clamp to demand, re-run max-min on residual capacity for
    still-hungry flows — converges quickly for our scenarios."""
    F = R.shape[0]
    x = jnp.zeros((F,), R.dtype)
    satisfied = jnp.zeros((F,), bool)

    def body(_, carry):
        x, satisfied = carry
        used = jnp.sum(R * x[:, None] * satisfied[:, None].astype(R.dtype), axis=0)
        resid = jnp.maximum(capacity - used, 0.0)
        mm = maxmin_rates(R, resid, (~satisfied).astype(R.dtype))
        newly = (~satisfied) & (mm >= demand)
        x = jnp.where(newly, demand, jnp.where(~satisfied, jnp.minimum(mm, demand), x))
        satisfied = satisfied | newly
        return x, satisfied

    x, _ = jax.lax.fori_loop(0, iters, body, (x, satisfied))
    return jnp.where(jnp.isfinite(x), x, demand)
