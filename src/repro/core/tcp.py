"""TCP baseline: per-flow max-min fair *rate* allocation (paper §VI-A.3).

The paper's baseline is the default transport of Storm/Heron/Flink — TCP
congestion control, which (idealized) converges to max-min fair rates among
flows sharing bottleneck links.

Two implementations live here:

* :func:`maxmin_fused` — the **hot-path solver**: a fused, fixed-trip-count
  progressive fill with per-flow demand caps folded directly into each
  round. ONE demand-rank matrix (the argsort as a 0/1 GEMM operand) is
  shared by every link; per round each link's exact saturation water level
  (``Σ_f min(d_f, θ) = resid_l``) drops out of batched rank-prefix sums —
  the allocator's weighted-simplex prefix rule (`_solve_link_block`)
  generalized to multi-link coupling. Every *locally minimal* link (no
  cheaper neighbor in the link-conflict graph) freezes per round, so the
  trip count tracks the depth of the strictly-increasing bottleneck-level
  chain, not the link count — and because the trip count is static there
  is **no ``lax.while_loop``**: the solver batches under `vmap`/SPMD
  sharding with zero data-dependent control flow.

* :func:`maxmin_rates` / :func:`demand_limited_maxmin` — the original
  while-loop progressive filling and its 4-round clamp-and-resolve demand
  wrapper, retained as **parity oracles** (same pattern as the allocator's
  `_per_link_rates_vmap`), plus :func:`demand_limited_maxmin_np`, a plain
  numpy sequential reference with unbounded rounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-9
_INF = jnp.inf

# Trip count of the hot-path fused fill. Each round freezes EVERY locally
# minimal bottleneck level in parallel, so rounds + 1 (the closing sweep
# resolves one further level) must cover the depth of the strictly-
# increasing bottleneck-level chain in the link-conflict graph — measured
# ≤ 3 across the seed-corpus routing structure, which 2 + sweep covers
# exactly: fleet trajectories are bitwise-identical to the while-loop
# oracle's at this setting (tests/test_maxmin_fused.py::TestCorpusRounds).
# The per-tick policy cost is (rounds + 1) water-level evaluations on a
# kernel-overhead-bound CPU path, so the default deliberately carries no
# slack. Deeper instances stay link-feasible (the sweep assigns
# min(demand, bottleneck level), which provably never oversubscribes a
# link); only the max-min refinement of the tail levels would be
# approximate. Pass ``rounds=None`` for the provably exact shape bound
# min(F, L) + 1 (each round saturates ≥ 1 link or demand-freezes every
# remaining flow).
FILL_ROUNDS = 2

_RTOL = 1e-6   # tie tolerance for water-level comparisons (relative)
_ATOL = 1e-6   # ... and absolute, for levels near zero


@functools.partial(jax.jit, static_argnames=())
def maxmin_rates(R: jnp.ndarray, capacity: jnp.ndarray,
                 active: jnp.ndarray | None = None) -> jnp.ndarray:
    """Exact max-min fair rates.

    R: [F, L] binary routing; capacity: [L]; active: [F] mask (default all).
    Flows traversing no link get rate +inf (caller clamps to demand).
    """
    F, L = R.shape
    if active is None:
        active = jnp.ones((F,), R.dtype)
    active = active.astype(R.dtype)
    on_net = (jnp.sum(R, axis=1) > 0) & (active > 0)

    def body(carry):
        x, frozen, link_done, _ = carry
        unfrozen = (~frozen) & on_net
        n_l = jnp.sum(R * unfrozen[:, None].astype(R.dtype), axis=0)      # [L]
        used = jnp.sum(R * (x * frozen.astype(R.dtype))[:, None], axis=0)  # [L]
        resid = jnp.maximum(capacity - used, 0.0)
        fair = jnp.where((n_l > 0) & (~link_done), resid / jnp.maximum(n_l, 1.0), _INF)
        share = jnp.min(fair)
        any_left = jnp.isfinite(share)
        # freeze EVERY link attaining the current water level at once
        # (classic progressive filling fills all tightest links together:
        # their unfrozen flows get the same share either way, so one round
        # per *bottleneck level* instead of one per bottleneck link)
        tight = (fair <= share) & any_left                           # [L]
        hit = jnp.any(R * tight[None, :].astype(R.dtype), axis=1) & unfrozen
        x = jnp.where(hit, share, x)
        frozen = frozen | hit
        link_done = link_done | tight
        return x, frozen, link_done, any_left

    x0 = jnp.zeros((F,), R.dtype)
    frozen0 = jnp.zeros((F,), bool)
    done0 = jnp.zeros((L,), bool)
    # while-loop instead of a fixed L-trip fori: each round freezes one
    # water level, and the loop exits as soon as no link has unfrozen
    # flows left — so the trip count tracks the scenario's *real* bottleneck
    # structure (#levels), not the (possibly padded — fleet engine) link
    # count. The body is idempotent once nothing binds.
    x, frozen, _, _ = jax.lax.while_loop(
        lambda c: c[3], body, (x0, frozen0, done0, jnp.array(True)))
    # flows not on any congested link (or off-net): unconstrained
    x = jnp.where(on_net & ~frozen, _INF, x)
    x = jnp.where(on_net, x, jnp.where(active > 0, _INF, 0.0))
    return x


def demand_limited_maxmin(R, capacity, demand, iters: int = 4):
    """Max-min with per-flow demand caps (flows never take more than they can
    send). Iterative: clamp to demand, re-run max-min on residual capacity for
    still-hungry flows — converges quickly for our scenarios."""
    F = R.shape[0]
    x = jnp.zeros((F,), R.dtype)
    satisfied = jnp.zeros((F,), bool)

    def body(_, carry):
        x, satisfied = carry
        used = jnp.sum(R * x[:, None] * satisfied[:, None].astype(R.dtype), axis=0)
        resid = jnp.maximum(capacity - used, 0.0)
        mm = maxmin_rates(R, resid, (~satisfied).astype(R.dtype))
        newly = (~satisfied) & (mm >= demand)
        x = jnp.where(newly, demand, jnp.where(~satisfied, jnp.minimum(mm, demand), x))
        satisfied = satisfied | newly
        return x, satisfied

    x, _ = jax.lax.fori_loop(0, iters, body, (x, satisfied))
    return jnp.where(jnp.isfinite(x), x, demand)


# --------------------------------------------------------------------------
# fused fixed-trip solver (the policy hot path)
# --------------------------------------------------------------------------
def _link_levels(A, m, resid):
    """Exact demand-capped saturation level θ_l per link: the unique θ with
    ``Σ_{unfrozen f on l} min(d_f, θ) = resid_l`` (+inf if the link cannot
    saturate: no unfrozen flows, or their total demand fits in resid).

    Rank-prefix form, no sorting: ``A`` stacks ``[W; 1; W·d; d]`` where
    ``W[f, g] = [d_g ≤ d_f]`` (ties broken by index) is the demand order as
    a 0/1 matrix — built once per solve — so EVERY per-link quantity the
    prefix rule needs (rank prefixes of counts and demands, plus their
    totals) drops out of ONE shared matmul ``A @ m`` per round in
    *original* flow order: under the fleet vmap a single batched GEMM,
    where per-link sorts (or batched cumsums) serialize on CPU backends.
    Selection needs no validity filter at all: the candidate level for the
    prefix capped at flow f is the root of the chord ``Σ_{d_g ≤ d_f} d_g +
    (#rest)·θ``, which upper-bounds ``Σ min(d, θ)`` pointwise, so every
    candidate root lower-bounds the true θ and the consistent prefix
    attains it — θ is simply the MAX over candidates (incl. the
    nothing-capped chord ``resid/n``). ``m`` [F, L] is the routing mask
    restricted to unfrozen flows. Returns θ [L].
    """
    F = m.shape[0]
    P = A @ m                                                 # [2F+2, L]
    cum_n, n_l = P[:F], P[F]
    cum_d, sum_d = P[F + 1:2 * F + 1], P[2 * F + 1]
    denom = n_l[None, :] - cum_n
    theta_k = (resid[None, :] - cum_d) / jnp.maximum(denom, 0.5)
    cand = jnp.where((m > 0) & (denom > 0.5), theta_k, -_INF)
    theta = jnp.maximum(jnp.max(cand, axis=0),
                        resid / jnp.maximum(n_l, 1.0))
    saturable = (n_l > 0) & (sum_d > resid * (1.0 + _RTOL) + _ATOL)
    return jnp.where(saturable, theta, _INF)


@functools.partial(jax.jit, static_argnames=("rounds",))
def maxmin_fused(R: jnp.ndarray, capacity: jnp.ndarray, demand: jnp.ndarray,
                 rounds: int | None = FILL_ROUNDS) -> jnp.ndarray:
    """Demand-limited max-min fair rates as a fused fixed-trip program.

    R: [F, L] binary routing; capacity: [L]; demand: [F] per-flow caps.
    Flows traversing no link get their demand (unconstrained), matching
    :func:`demand_limited_maxmin`. ``rounds=None`` selects the provably
    exact shape bound min(F, L) + 1; the default ``FILL_ROUNDS`` is exact
    whenever the bottleneck-level chain is no deeper (always, on the seed
    corpus) and link-feasible regardless.

    Per round: compute every link's exact demand-capped water level θ_l
    (:func:`_link_levels`), then freeze every link that is *locally
    minimal* — θ_l ≤ θ_m for every link m sharing an unfrozen flow — at its
    level, its flows at ``min(d_f, θ_l)``, plus every flow whose demand is
    covered by all of its links (``d_f ≤ min_l θ_l``). Water levels are
    monotone nondecreasing across rounds, so locally minimal freezing is
    confluent with classic sequential progressive filling: the rounds
    needed equal the depth of the increasing bottleneck-level chain. A
    closing sweep assigns any still-unfrozen flow ``min(d_f, min_l θ_l)``,
    which never oversubscribes a link (Σ_f min(d_f, θ_flow) ≤
    Σ_f min(d_f, θ_l) = resid_l), so truncated runs stay feasible.
    """
    F, L = R.shape
    if rounds is None:
        rounds = min(F, L) + 1
    R = R.astype(jnp.float32)
    on_net = jnp.sum(R, axis=1) > 0
    d = jnp.where(on_net, jnp.maximum(demand, 0.0), 0.0)
    # demand rank order as a 0/1 matrix (ties by flow index): the shared
    # "argsort" of the fill, built once per solve. Stacked with its
    # demand-weighted form and two total rows into ONE left operand so each
    # round's prefix sums and totals are a single GEMM (`_link_levels`).
    idx = jnp.arange(F)
    W = ((d[None, :] < d[:, None])
         | ((d[None, :] == d[:, None])
            & (idx[None, :] <= idx[:, None]))).astype(jnp.float32)
    A = jnp.concatenate([W, jnp.ones((1, F), jnp.float32),
                         W * d[None, :], d[None, :]], axis=0)  # [2F+2, F]

    def body(_, carry):
        x, frozen, resid = carry
        u = (~frozen) & on_net
        m = R * u[:, None].astype(R.dtype)                    # [F, L]
        theta = _link_levels(A, m, resid)                     # [L]
        # per-flow bottleneck level: tightest link on the flow's route
        th_flow = jnp.min(jnp.where(R > 0, theta[None, :], _INF), axis=1)
        # locally minimal links: no unfrozen flow of theirs sees a tighter
        # link elsewhere (th_flow ≤ θ_l always, so this is a tie test)
        nbr = jnp.min(jnp.where(m > 0, th_flow[:, None], _INF), axis=0)
        freeze_l = jnp.isfinite(theta) & (
            theta <= nbr * (1.0 + _RTOL) + _ATOL)
        hit = (jnp.sum(R * freeze_l[None, :].astype(R.dtype), axis=1)
               > 0) & u
        sated = u & (d <= th_flow * (1.0 + _RTOL) + _ATOL)
        newf = hit | sated
        vals = jnp.minimum(d, th_flow)        # th_flow=inf → demand
        x = jnp.where(newf, vals, x)
        resid = jnp.maximum(
            resid - jnp.where(newf, vals, 0.0) @ R, 0.0)
        return x, frozen | newf, resid

    x0 = jnp.zeros((F,), jnp.float32)
    frozen0 = ~on_net    # off-net flows take no capacity; handled below
    x, frozen, resid = jax.lax.fori_loop(
        0, rounds, body, (x0, frozen0, capacity.astype(jnp.float32)))
    # closing sweep: any leftover flow rides its current bottleneck level —
    # always link-feasible, exact when the loop already converged
    m = R * ((~frozen) & on_net)[:, None].astype(R.dtype)
    theta = _link_levels(A, m, resid)
    th_flow = jnp.min(jnp.where(R > 0, theta[None, :], _INF), axis=1)
    x = jnp.where(frozen, x, jnp.minimum(d, th_flow))
    return jnp.where(on_net, x, demand)


def demand_limited_maxmin_np(R, capacity, demand):
    """Plain-numpy sequential progressive filling with demand caps —
    unbounded rounds, one bottleneck event at a time. The slow, obviously-
    correct reference the fused solver (and the while-loop oracles) are
    property-tested against."""
    R = np.asarray(R, np.float64)
    resid = np.asarray(capacity, np.float64).copy()
    d = np.asarray(demand, np.float64)
    F, L = R.shape
    on_net = R.sum(1) > 0
    x = np.where(on_net, 0.0, d)
    frozen = ~on_net
    d = np.where(on_net, np.maximum(d, 0.0), 0.0)
    for _ in range(F + L + 1):
        u = ~frozen
        if not u.any():
            break
        # exact saturation level per link (sort the link's own demands)
        theta = np.full(L, np.inf)
        for link in range(L):
            f = u & (R[:, link] > 0)
            n = int(f.sum())
            if n == 0 or d[f].sum() <= resid[link] + 1e-12:
                continue  # link cannot saturate: no level
            ds = np.sort(d[f])
            capped = 0.0
            for k in range(n):
                t = (resid[link] - capped) / (n - k)
                if t <= ds[k] + 1e-15:   # guaranteed for some k: Σd > resid
                    theta[link] = t
                    break
                capped += ds[k]
        th_flow = np.where(
            on_net, np.min(np.where(R > 0, theta[None, :], np.inf), 1), np.inf
        )
        lvl = np.inf if not u.any() else np.nanmin(th_flow[u])
        # freeze demand-satisfied flows first, else the single tightest level
        sated = u & (d <= th_flow + 1e-12)
        if sated.any():
            newf = sated
        else:
            newf = u & (th_flow <= lvl * (1 + 1e-12))
        vals = np.minimum(d, th_flow)
        x = np.where(newf, vals, x)
        resid = np.maximum(resid - (vals * newf) @ R, 0.0)
        frozen |= newf
    return x
