"""TCP baseline: per-flow max-min fair *rate* allocation (paper §VI-A.3).

The paper's baseline is the default transport of Storm/Heron/Flink — TCP
congestion control, which (idealized) converges to max-min fair rates among
flows sharing bottleneck links.

Three implementations live here:

* :func:`maxmin_fused` — the **hot-path solver**: a fused, fixed-trip-count
  progressive fill with per-flow demand caps folded directly into each
  round. ONE demand-rank matrix (the argsort as a 0/1 GEMM operand) is
  shared by every link; per round each link's exact saturation water level
  (``Σ_f min(d_f, θ) = resid_l``) drops out of batched rank-prefix sums —
  the allocator's weighted-simplex prefix rule (`_solve_link_block`)
  generalized to multi-link coupling. Every *locally minimal* link (no
  cheaper neighbor in the link-conflict graph) freezes per round, so the
  trip count tracks the depth of the strictly-increasing bottleneck-level
  chain, not the link count — and because the trip count is static there
  is **no ``lax.while_loop``**: the solver batches under `vmap`/SPMD
  sharding with zero data-dependent control flow.

  Two *forms* of the per-round water-level evaluation exist behind a
  shape-dependent crossover dispatched at trace time
  (:data:`MAXMIN_CROSSOVER_F`): the **GEMM form** keeps the rank prefixes
  as one ``[F+1, F] @ [F, 2L]`` matmul against the order-only operand
  ``[W; 1]`` (demand folded into the *right* operand — exact in {0, 1}
  arithmetic, so bitwise-identical to the PR-4 stacked ``[2F+2, F]``
  layout), which wins in the op-overhead-bound small-F regime where
  batched sorts serialize on CPU; the **sorted form** replaces the
  O(F²·L) GEMM with one stable argsort + two batched cumsums (O(F·L)),
  which wins once F is large enough that FLOPs beat op overhead. The GEMM
  form additionally chunks its candidate rows in ``block_flows`` blocks
  (mirroring the allocator's ``block_links``) so the [F, L] candidate
  intermediates stay cache-bounded at mid-size F.

* :func:`maxmin_fused_step` / :func:`maxmin_order_init` — the **order-
  cached** variant for per-tick re-solves inside a scan: the rank operand
  is a pure function of the *demand order*, which between adjacent control
  ticks changes rarely, so the carry holds ``(valid, perm, A1)`` and an
  O(F) monotonicity check against the carried permutation decides whether
  the carried operand is still the exact stable order. The rebuild path
  is the same construction as the fresh solve (W from lexicographic
  comparisons), and a kept operand is bitwise-identical to a rebuilt one
  (W is a function of the order alone), so carried and fresh solves agree
  bitwise. The permutation rebuild derives from W's row sums via a
  one-hot contraction — no argsort in the rebuild path, so the carried
  step stays GEMM/elementwise-only under the fleet vmap.

* :func:`maxmin_rates` / :func:`demand_limited_maxmin` — the while-loop
  progressive-filling oracles (same pattern as the allocator's
  `_per_link_rates_vmap`), plus :func:`demand_limited_maxmin_np`, a plain
  numpy sequential reference with unbounded rounds.
  ``demand_limited_maxmin`` is true sequential progressive filling with
  demand caps (per-link levels by bisection — independent math from both
  fused forms); the PR-4 clamp-and-resolve wrapper it replaces froze a
  flow at its demand whenever its *demand-free* max-min share covered the
  demand, which is unsound — demand caps elsewhere can raise competitors'
  rates and pull the flow's final level *below* its demand (seed 5041 of
  the property suite) — so the oracle now passes the KKT certificate
  unconditionally.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-9
_INF = jnp.inf

# Trip count of the hot-path fused fill. Each round freezes EVERY locally
# minimal bottleneck level in parallel, so rounds + 1 (the closing sweep
# resolves one further level) must cover the depth of the strictly-
# increasing bottleneck-level chain in the link-conflict graph — measured
# ≤ 3 across the seed-corpus routing structure, which 2 + sweep covers
# exactly: fleet trajectories are bitwise-identical to the while-loop
# oracle's at this setting (tests/test_maxmin_fused.py::TestCorpusRounds).
# The per-tick policy cost is (rounds + 1) water-level evaluations on a
# kernel-overhead-bound CPU path, so the default deliberately carries no
# slack. Deeper instances stay link-feasible (the sweep assigns
# min(demand, bottleneck level), which provably never oversubscribes a
# link); only the max-min refinement of the tail levels would be
# approximate. Pass ``rounds=None`` for the provably exact shape bound
# min(F, L) + 1 (each round saturates ≥ 1 link or demand-freezes every
# remaining flow).
FILL_ROUNDS = 2

_RTOL = 1e-6   # tie tolerance for water-level comparisons (relative)
_ATOL = 1e-6   # ... and absolute, for levels near zero

# Crossover between the two water-level forms, by (padded) flow count at
# trace time: below it the rank-prefix GEMM form wins (op-overhead-bound
# CPU regime — batched per-link cumsums/gathers serialize), at or above it
# the argsort+cumsum form's O(F·L) beats the GEMM's O(F²·L). Calibrated by
# the ``maxmin_crossover`` rows of ``benchmarks/allocator.py`` (vmap-8,
# the fleet engine's batching shape): 256 is the first grid point where
# the sorted form won in BOTH calibration runs (run-to-run noise on the
# shared container flips the 96–192 band; sorted's margin grows to ~2x by
# F=512) — see BENCH_allocator.json. Every fleet-corpus bucket (F ≤ 28)
# sits well below it, so the fleet path stays on the bitwise-stable GEMM
# form.
MAXMIN_CROSSOVER_F = 256

# GEMM-form candidate rows are processed in chunks of this size once F
# outgrows ``2 * MAXMIN_BLOCK_FLOWS`` (mirroring the allocator's
# ``block_links``): the [F, L] candidate/prefix intermediates of a
# mid-size instance stay cache-bounded while small (fleet-corpus) shapes
# keep the single-pass — and bitwise-unchanged — layout.
MAXMIN_BLOCK_FLOWS = 64

# rounds at or below this unroll as straight-line code (bitwise-identical
# to the fori_loop form; lets XLA fuse the elementwise chains across round
# boundaries instead of walling them behind a while op), above it the
# rolled loop keeps compile time bounded for ``rounds=None`` deep bounds
_UNROLL_ROUNDS = 4


@functools.partial(jax.jit, static_argnames=())
def maxmin_rates(R: jnp.ndarray, capacity: jnp.ndarray,
                 active: jnp.ndarray | None = None) -> jnp.ndarray:
    """Exact max-min fair rates (no demand caps).

    R: [F, L] binary routing; capacity: [L]; active: [F] mask (default all).
    Flows traversing no link get rate +inf (caller clamps to demand).
    """
    F, L = R.shape
    if active is None:
        active = jnp.ones((F,), R.dtype)
    active = active.astype(R.dtype)
    on_net = (jnp.sum(R, axis=1) > 0) & (active > 0)

    def body(carry):
        x, frozen, link_done, _ = carry
        unfrozen = (~frozen) & on_net
        n_l = jnp.sum(R * unfrozen[:, None].astype(R.dtype), axis=0)      # [L]
        used = jnp.sum(R * (x * frozen.astype(R.dtype))[:, None], axis=0)  # [L]
        resid = jnp.maximum(capacity - used, 0.0)
        fair = jnp.where((n_l > 0) & (~link_done), resid / jnp.maximum(n_l, 1.0), _INF)
        share = jnp.min(fair)
        any_left = jnp.isfinite(share)
        # freeze EVERY link attaining the current water level at once
        # (classic progressive filling fills all tightest links together:
        # their unfrozen flows get the same share either way, so one round
        # per *bottleneck level* instead of one per bottleneck link)
        tight = (fair <= share) & any_left                           # [L]
        hit = jnp.any(R * tight[None, :].astype(R.dtype), axis=1) & unfrozen
        x = jnp.where(hit, share, x)
        frozen = frozen | hit
        link_done = link_done | tight
        return x, frozen, link_done, any_left

    x0 = jnp.zeros((F,), R.dtype)
    frozen0 = jnp.zeros((F,), bool)
    done0 = jnp.zeros((L,), bool)
    # while-loop instead of a fixed L-trip fori: each round freezes one
    # water level, and the loop exits as soon as no link has unfrozen
    # flows left — so the trip count tracks the scenario's *real* bottleneck
    # structure (#levels), not the (possibly padded — fleet engine) link
    # count. The body is idempotent once nothing binds.
    x, frozen, _, _ = jax.lax.while_loop(
        lambda c: c[3], body, (x0, frozen0, done0, jnp.array(True)))
    # flows not on any congested link (or off-net): unconstrained
    x = jnp.where(on_net & ~frozen, _INF, x)
    x = jnp.where(on_net, x, jnp.where(active > 0, _INF, 0.0))
    return x


def demand_limited_maxmin(R, capacity, demand, iters: int | None = None):
    """Max-min with per-flow demand caps: sequential progressive filling,
    one bottleneck event per round, per-link saturation levels by
    **bisection** — deliberately independent math from both fused forms,
    so it stays a real oracle.

    Replaces the PR-4 clamp-and-resolve wrapper, whose freeze rule
    ("clamp at demand when the demand-free max-min share covers it") is
    unsound: capping *other* flows at their demands can raise this flow's
    competitors on a shared link and pull its final fair level below its
    own demand, so the premature clamp over-allocates (seed 5041 — the
    wrapper converged to a feasible, work-conserving fixed point that
    fails the KKT certificate). Progressive filling freezes only sated
    flows and global-minimum bottleneck levels, both of which are final
    by the water-filling monotonicity argument, so the fixed point here
    *is* the max-min allocation and the certificate holds unconditionally
    (tests/test_maxmin_fused.py).

    ``iters`` caps the outer rounds (default F + L + 1, the convergence
    bound: every round freezes at least one flow or terminates).
    """
    F, L = R.shape
    R = R.astype(jnp.float32)
    on_net = jnp.sum(R, axis=1) > 0
    d = jnp.where(on_net, jnp.maximum(demand, 0.0), 0.0)
    if iters is None:
        iters = F + L + 1

    def link_theta(m, resid):
        # exact θ_l with Σ_{unfrozen f on l} min(d_f, θ) = resid_l, by 50
        # bisection steps on [0, resid_l] (Σ min(d, θ) is nondecreasing in
        # θ and θ* ≤ resid_l whenever the link can saturate): float32
        # interval width resid·2⁻⁵⁰, far inside the solver tie tolerance
        n_l = jnp.sum(m, axis=0)
        sum_d = jnp.sum(d[:, None] * m, axis=0)
        saturable = (n_l > 0) & (sum_d > resid * (1.0 + _RTOL) + _ATOL)

        def bis(_, lohi):
            # Σ min(d, mid) > resid → the level lies below mid, else above
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            s = jnp.sum(jnp.minimum(d[:, None], mid[None, :]) * m, axis=0)
            over = s > resid
            return jnp.where(over, lo, mid), jnp.where(over, mid, hi)

        lo, hi = jax.lax.fori_loop(
            0, 50, bis, (jnp.zeros_like(resid), jnp.maximum(resid, 0.0)))
        return jnp.where(saturable, 0.5 * (lo + hi), _INF)

    def cond(c):
        _, frozen, _, progressed, rounds = c
        return progressed & jnp.any(~frozen) & (rounds < iters)

    def body(c):
        x, frozen, resid, _, rounds = c
        u = ~frozen
        m = R * u[:, None].astype(R.dtype)
        theta = link_theta(m, resid)
        th_flow = jnp.min(jnp.where(R > 0, theta[None, :], _INF), axis=1)
        # demand-satisfied flows freeze first (their level can only rise);
        # otherwise the single tightest water level (+ ties) is final
        sated = u & (d <= th_flow * (1.0 + _RTOL) + _ATOL)
        lvl = jnp.min(jnp.where(u, th_flow, _INF))
        at_lvl = u & (th_flow <= lvl * (1.0 + _RTOL) + _ATOL)
        newf = jnp.where(jnp.any(sated), sated, at_lvl)
        vals = jnp.minimum(d, th_flow)           # th_flow = inf → demand
        x = jnp.where(newf, vals, x)
        resid = jnp.maximum(resid - jnp.where(newf, vals, 0.0) @ R, 0.0)
        return x, frozen | newf, resid, jnp.any(newf), rounds + 1

    x0 = jnp.where(on_net, 0.0, jnp.asarray(demand, jnp.float32))
    x, *_ = jax.lax.while_loop(
        cond, body,
        (x0, ~on_net, capacity.astype(jnp.float32), jnp.array(True),
         jnp.asarray(0, jnp.int32)))
    return x


# --------------------------------------------------------------------------
# fused fixed-trip solver (the policy hot path)
# --------------------------------------------------------------------------
def _order_matrix(d):
    """Demand rank order as a 0/1 matrix plus the matching stable-sort
    permutation: ``W[f, g] = [(d_g, g) ≤lex (d_f, f)]`` (ties broken by
    flow index — exactly ``jnp.argsort(d, stable=True)``'s order). The
    permutation derives from W's row sums through a one-hot contraction
    (``rank[f]`` is f's position in the stable order, so scattering
    ``f → rank[f]`` inverts it) instead of an argsort: the order-cache
    rebuild stays GEMM/elementwise-only, which matters under the fleet
    vmap where a per-tick batched sort would serialize on CPU backends."""
    F = d.shape[0]
    idx = jnp.arange(F)
    W = ((d[None, :] < d[:, None])
         | ((d[None, :] == d[:, None])
            & (idx[None, :] <= idx[:, None]))).astype(jnp.float32)
    rank = jnp.sum(W, axis=1).astype(jnp.int32) - 1             # [F]
    perm = jnp.sum(jnp.where(rank[None, :] == idx[:, None],
                             idx[None, :], 0), axis=1)          # [F] int32
    return W, perm


def _order_operand(d):
    """The order-only left GEMM operand ``A1 = [W; 1]`` ([F+1, F]) and the
    stable permutation it encodes. A1 is a pure function of the demand
    *order*: two demand vectors with the same stable order produce
    bitwise-identical operands, which is what makes the order cache's
    kept-vs-rebuilt branches interchangeable."""
    F = d.shape[0]
    W, perm = _order_matrix(d)
    A1 = jnp.concatenate([W, jnp.ones((1, F), jnp.float32)], axis=0)
    return A1, perm


def _theta_from_parts(m_or_ms, n_l, sum_d, cum_n, cum_d, resid):
    """Shared tail of every water-level form: candidate chord roots →
    max-selection → saturability gate (see :func:`_link_levels`)."""
    denom = n_l[None, :] - cum_n
    theta_k = (resid[None, :] - cum_d) / jnp.maximum(denom, 0.5)
    cand = jnp.where((m_or_ms > 0) & (denom > 0.5), theta_k, -_INF)
    theta = jnp.maximum(jnp.max(cand, axis=0),
                        resid / jnp.maximum(n_l, 1.0))
    saturable = (n_l > 0) & (sum_d > resid * (1.0 + _RTOL) + _ATOL)
    return jnp.where(saturable, theta, _INF)


def _link_levels(A1, d, m, resid):
    """Exact demand-capped saturation level θ_l per link: the unique θ with
    ``Σ_{unfrozen f on l} min(d_f, θ) = resid_l`` (+inf if the link cannot
    saturate: no unfrozen flows, or their total demand fits in resid).

    GEMM form, no sorting: ``A1 = [W; 1]`` where ``W[f, g] = [d_g ≤ d_f]``
    (ties by index) is the demand order as a 0/1 matrix — order-only, so
    the order cache can carry it across ticks — and the demand weighting
    rides in the *right* operand: ``P = A1 @ [m | d·m]`` ([F+1, 2L])
    yields every per-link quantity the prefix rule needs (rank prefixes of
    counts and demands, plus their totals) in one shared matmul per round
    in *original* flow order. W and m are {0, 1}-valued, so folding d
    right is exact: each product term equals the PR-4 stacked
    ``[W; 1; W·d; d] @ m`` layout's term bitwise (verified property-wise;
    the fleet path relies on it). Selection needs no validity filter: the
    candidate level for the prefix capped at flow f is the root of the
    chord ``Σ_{d_g ≤ d_f} d_g + (#rest)·θ``, which upper-bounds
    ``Σ min(d, θ)`` pointwise, so every candidate root lower-bounds the
    true θ and the consistent prefix attains it — θ is simply the MAX over
    candidates (incl. the nothing-capped chord ``resid/n``). ``m`` [F, L]
    is the routing mask restricted to unfrozen flows. Returns θ [L].
    """
    F, L = m.shape
    P = A1 @ jnp.concatenate([m, d[:, None] * m], axis=1)     # [F+1, 2L]
    return _theta_from_parts(m, P[F, :L], P[F, L:], P[:F, :L], P[:F, L:],
                             resid)


def _link_levels_blocked(A1, d, m, resid, block_flows: int):
    """GEMM form with the candidate rows processed in ``block_flows``
    chunks under ``lax.map`` (mirroring the allocator's ``block_links``):
    the [F, 2L] prefix / [F, L] candidate intermediates are capped at
    [block, ·] while only the rank operand and routing mask stay
    full-size. The per-chunk maxima combine by ``max`` — exact and
    associative — and each chunk's GEMM rows contract identically to the
    single-pass form, so chunking changes wall-clock working set, not
    semantics (parity-tested at ≤1e-5; the fleet corpus never takes this
    path — it activates only above ``2 * MAXMIN_BLOCK_FLOWS`` flows)."""
    F, L = m.shape
    rhs = jnp.concatenate([m, d[:, None] * m], axis=1)        # [F, 2L]
    tot = A1[F] @ rhs                                         # [2L]
    n_l, sum_d = tot[:L], tot[L:]
    blk = max(int(block_flows), 1)
    nb = -(-F // blk)
    pad = nb * blk - F
    # padded rows: zero rank rows and zero mask → candidates -inf, inert
    Ap = jnp.pad(A1[:F], ((0, pad), (0, 0)))
    mp = jnp.pad(m, ((0, pad), (0, 0)))

    def chunk(args):
        Ac, mc = args                       # [blk, F], [blk, L]
        Pc = Ac @ rhs                       # [blk, 2L]
        denom = n_l[None, :] - Pc[:, :L]
        theta_k = (resid[None, :] - Pc[:, L:]) / jnp.maximum(denom, 0.5)
        cand = jnp.where((mc > 0) & (denom > 0.5), theta_k, -_INF)
        return jnp.max(cand, axis=0)        # [L]

    cmax = jax.lax.map(chunk, (Ap.reshape(nb, blk, F),
                               mp.reshape(nb, blk, L)))
    theta = jnp.maximum(jnp.max(cmax, axis=0),
                        resid / jnp.maximum(n_l, 1.0))
    saturable = (n_l > 0) & (sum_d > resid * (1.0 + _RTOL) + _ATOL)
    return jnp.where(saturable, theta, _INF)


def _link_levels_sorted(perm, d_s, m, resid):
    """Sorted (argsort + cumsum) form of the same water level: gather the
    mask rows into stable demand order once, then the rank prefixes are
    two batched cumsums — O(F·L) against the GEMM form's O(F²·L), which
    wins once F clears :data:`MAXMIN_CROSSOVER_F` (below it the batched
    gathers/cumsums serialize on CPU and lose to the one GEMM). The max
    over candidates is order-independent, so no un-sort is needed."""
    m_s = m[perm]                                             # [F, L]
    cum_n = jnp.cumsum(m_s, axis=0)
    cum_d = jnp.cumsum(d_s[:, None] * m_s, axis=0)
    return _theta_from_parts(m_s, cum_n[-1], cum_d[-1], cum_n, cum_d, resid)


def _fill(R, on_net, d, levels, capacity, rounds: int):
    """The progressive fill itself, generic over the water-level form.

    Per round: compute every link's exact demand-capped water level θ_l,
    then freeze every link that is *locally minimal* — θ_l ≤ θ_m for every
    link m sharing an unfrozen flow — at its level, its flows at
    ``min(d_f, θ_l)``, plus every flow whose demand is covered by all of
    its links (``d_f ≤ min_l θ_l``). Water levels are monotone
    nondecreasing across rounds, so locally minimal freezing is confluent
    with classic sequential progressive filling: the rounds needed equal
    the depth of the increasing bottleneck-level chain. A closing sweep
    assigns any still-unfrozen flow ``min(d_f, min_l θ_l)``, which never
    oversubscribes a link (Σ_f min(d_f, θ_flow) ≤ Σ_f min(d_f, θ_l) =
    resid_l), so truncated runs stay feasible. Small round counts unroll
    (bitwise-identical to the rolled loop; XLA then fuses the elementwise
    chains across round boundaries instead of walling them behind a while
    op — the op-overhead-bound fleet regime's main saving)."""
    def body(carry):
        x, frozen, resid = carry
        u = (~frozen) & on_net
        m = R * u[:, None].astype(R.dtype)                    # [F, L]
        theta = levels(m, resid)                              # [L]
        # per-flow bottleneck level: tightest link on the flow's route
        th_flow = jnp.min(jnp.where(R > 0, theta[None, :], _INF), axis=1)
        # locally minimal links: no unfrozen flow of theirs sees a tighter
        # link elsewhere (th_flow ≤ θ_l always, so this is a tie test)
        nbr = jnp.min(jnp.where(m > 0, th_flow[:, None], _INF), axis=0)
        freeze_l = jnp.isfinite(theta) & (
            theta <= nbr * (1.0 + _RTOL) + _ATOL)
        hit = (jnp.sum(R * freeze_l[None, :].astype(R.dtype), axis=1)
               > 0) & u
        sated = u & (d <= th_flow * (1.0 + _RTOL) + _ATOL)
        newf = hit | sated
        vals = jnp.minimum(d, th_flow)        # th_flow=inf → demand
        x = jnp.where(newf, vals, x)
        resid = jnp.maximum(
            resid - jnp.where(newf, vals, 0.0) @ R, 0.0)
        return x, frozen | newf, resid

    carry = (jnp.zeros((R.shape[0],), jnp.float32), ~on_net,
             capacity.astype(jnp.float32))
    if rounds <= _UNROLL_ROUNDS:
        for _ in range(rounds):
            carry = body(carry)
    else:
        carry = jax.lax.fori_loop(0, rounds, lambda _, c: body(c), carry)
    x, frozen, resid = carry
    # closing sweep: any leftover flow rides its current bottleneck level —
    # always link-feasible, exact when the loop already converged
    m = R * ((~frozen) & on_net)[:, None].astype(R.dtype)
    theta = levels(m, resid)
    th_flow = jnp.min(jnp.where(R > 0, theta[None, :], _INF), axis=1)
    return jnp.where(frozen, x, jnp.minimum(d, th_flow))


def _resolve_form(F: int, form: str | None) -> str:
    if form is None:
        return "sorted" if F >= MAXMIN_CROSSOVER_F else "gemm"
    if form not in ("gemm", "sorted"):
        raise ValueError(f"unknown maxmin form {form!r}")
    return form


def _resolve_block_flows(F: int, form: str, block_flows: int | None):
    if form != "gemm":
        return None
    if block_flows is None:
        return MAXMIN_BLOCK_FLOWS if F > 2 * MAXMIN_BLOCK_FLOWS else None
    return int(block_flows) if block_flows > 0 else None


def _levels_fn(form: str, d, A1, perm, block_flows):
    """Bind the chosen water-level form over its order machinery."""
    if form == "gemm":
        if block_flows is not None:
            return lambda m, resid: _link_levels_blocked(
                A1, d, m, resid, block_flows)
        return lambda m, resid: _link_levels(A1, d, m, resid)
    d_s = d[perm]
    return lambda m, resid: _link_levels_sorted(perm, d_s, m, resid)


@functools.partial(jax.jit,
                   static_argnames=("rounds", "form", "block_flows"))
def maxmin_fused(R: jnp.ndarray, capacity: jnp.ndarray, demand: jnp.ndarray,
                 rounds: int | None = FILL_ROUNDS,
                 form: str | None = None,
                 block_flows: int | None = None) -> jnp.ndarray:
    """Demand-limited max-min fair rates as a fused fixed-trip program.

    R: [F, L] binary routing; capacity: [L]; demand: [F] per-flow caps.
    Flows traversing no link get their demand (unconstrained), matching
    :func:`demand_limited_maxmin`. ``rounds=None`` selects the provably
    exact shape bound min(F, L) + 1; the default ``FILL_ROUNDS`` is exact
    whenever the bottleneck-level chain is no deeper (always, on the seed
    corpus) and link-feasible regardless.

    ``form`` picks the water-level evaluation: ``"gemm"`` (rank-prefix
    GEMM against the order-only operand), ``"sorted"`` (stable argsort +
    batched cumsums), or ``None`` — the default — for the trace-time
    crossover on the (padded) flow count against
    :data:`MAXMIN_CROSSOVER_F`. The choice is a python-level branch on a
    static shape, so it can never retrigger compilation at run time and
    is constant per fleet bucket. ``block_flows`` chunks the GEMM form's
    candidate rows (``None`` = auto: single-pass below
    ``2 * MAXMIN_BLOCK_FLOWS`` flows).
    """
    F, L = R.shape
    if rounds is None:
        rounds = min(F, L) + 1
    form = _resolve_form(F, form)
    block_flows = _resolve_block_flows(F, form, block_flows)
    R = R.astype(jnp.float32)
    on_net = jnp.sum(R, axis=1) > 0
    d = jnp.where(on_net, jnp.maximum(demand, 0.0), 0.0)
    if form == "gemm":
        A1, perm = _order_operand(d)
    else:
        A1 = None
        perm = jnp.argsort(d, stable=True)
    levels = _levels_fn(form, d, A1, perm, block_flows)
    x = _fill(R, on_net, d, levels, capacity, rounds)
    return jnp.where(on_net, x, demand)


# --------------------------------------------------------------------------
# order-cached per-tick stepping (the in-scan hot path)
# --------------------------------------------------------------------------
def maxmin_order_init(F: int, form: str | None = None):
    """Initial (invalid) order-cache carry for a scan over per-tick
    solves: ``(valid, perm, A1)``. The first step always rebuilds (and
    counts as one rebuild — the perf gate's static-demand invariant is
    exactly one rebuild per trajectory). The carried operand's shape
    follows the form the crossover will pick for this F: the sorted form
    carries no rank matrix (A1 is [0, F]), the GEMM form carries the full
    [F+1, F] operand."""
    form = _resolve_form(F, form)
    rows = F + 1 if form == "gemm" else 0
    return (jnp.zeros((), bool), jnp.arange(F, dtype=jnp.int32),
            jnp.zeros((rows, F), jnp.float32))


def maxmin_fused_step(R: jnp.ndarray, capacity: jnp.ndarray,
                      demand: jnp.ndarray, carry,
                      rounds: int | None = FILL_ROUNDS,
                      form: str | None = None,
                      block_flows: int | None = None):
    """One order-cached solve: :func:`maxmin_fused` semantics (bitwise),
    amortizing the demand-order machinery across ticks.

    ``carry`` is ``(valid, perm, A1)`` from :func:`maxmin_order_init` or a
    previous step. An O(F) monotonicity check of the current (clamped)
    demands against the carried permutation — ``(d[perm], perm)`` must be
    strictly increasing in lexicographic order, which characterizes perm
    as *the* stable sort of d — decides whether the carried operand still
    encodes the exact order; only on a change is it rebuilt, by the same
    construction the fresh solver uses. Kept and rebuilt operands are
    bitwise-identical whenever the check passes (A1 is a function of the
    order alone), so the solve output never depends on the cache's hit
    pattern. Under the fleet vmap the keep/rebuild ``lax.cond`` lowers to
    a select (both arms execute per batch member); the savings there come
    from the order-only operand and the unrolled fill, while the
    *sequential* scan path takes the branch for real. Returns
    ``(x, carry', rebuilt)`` with ``rebuilt`` a bool scalar (one per
    batch member under vmap) for rebuild-count accounting.

    Not jitted itself: it is scan-body machinery, traced inside its
    caller (``repro.streams.simulator._run``).
    """
    F, L = R.shape
    if rounds is None:
        rounds = min(F, L) + 1
    form = _resolve_form(F, form)
    block_flows = _resolve_block_flows(F, form, block_flows)
    R = R.astype(jnp.float32)
    on_net = jnp.sum(R, axis=1) > 0
    d = jnp.where(on_net, jnp.maximum(demand, 0.0), 0.0)

    valid0, perm0, A1_0 = carry
    dp = d[perm0]
    if F > 1:
        mono = jnp.all((dp[:-1] < dp[1:])
                       | ((dp[:-1] == dp[1:]) & (perm0[:-1] < perm0[1:])))
    else:
        mono = jnp.array(True)
    ok = valid0 & mono

    def rebuild(_):
        if form == "gemm":
            A1, perm = _order_operand(d)
        else:
            _, perm = _order_matrix(d)
            A1 = jnp.zeros((0, F), jnp.float32)
        return perm, A1

    def keep(_):
        return perm0, A1_0

    perm, A1 = jax.lax.cond(ok, keep, rebuild, None)
    levels = _levels_fn(form, d, A1, perm, block_flows)
    x = _fill(R, on_net, d, levels, capacity, rounds)
    x = jnp.where(on_net, x, demand)
    return x, (jnp.ones((), bool), perm, A1), ~ok


def demand_limited_maxmin_np(R, capacity, demand):
    """Plain-numpy sequential progressive filling with demand caps —
    unbounded rounds, one bottleneck event at a time. The slow, obviously-
    correct reference the fused solver (and the while-loop oracles) are
    property-tested against."""
    R = np.asarray(R, np.float64)
    resid = np.asarray(capacity, np.float64).copy()
    d = np.asarray(demand, np.float64)
    F, L = R.shape
    on_net = R.sum(1) > 0
    x = np.where(on_net, 0.0, d)
    frozen = ~on_net
    d = np.where(on_net, np.maximum(d, 0.0), 0.0)
    for _ in range(F + L + 1):
        u = ~frozen
        if not u.any():
            break
        # exact saturation level per link (sort the link's own demands)
        theta = np.full(L, np.inf)
        for link in range(L):
            f = u & (R[:, link] > 0)
            n = int(f.sum())
            if n == 0 or d[f].sum() <= resid[link] + 1e-12:
                continue  # link cannot saturate: no level
            ds = np.sort(d[f])
            capped = 0.0
            for k in range(n):
                t = (resid[link] - capped) / (n - k)
                if t <= ds[k] + 1e-15:   # guaranteed for some k: Σd > resid
                    theta[link] = t
                    break
                capped += ds[k]
        th_flow = np.where(
            on_net, np.min(np.where(R > 0, theta[None, :], np.inf), 1), np.inf
        )
        lvl = np.inf if not u.any() else np.nanmin(th_flow[u])
        # freeze demand-satisfied flows first, else the single tightest level
        sated = u & (d <= th_flow + 1e-12)
        if sated.any():
            newf = sated
        else:
            newf = u & (th_flow <= lvl * (1 + 1e-12))
        vals = np.minimum(d, th_flow)
        x = np.where(newf, vals, x)
        resid = np.maximum(resid - (vals * newf) @ R, 0.0)
        frozen |= newf
    return x
