"""Flow-state model (paper Fig. 5).

Per flow ``f`` and measurement interval ``(t, t+dt)`` the profiler reports the
5-metric tuple

    ⟨ L_f^s(t),  L_f^r(t),  V_f(t,t+dt),  L_f^s(t+dt),  L_f^r(t+dt) ⟩

where ``L^s`` is the *sender* queue backlog (MB of tuples awaiting transfer —
fork side), ``L^r`` the *receiver* queue backlog (MB received but not yet
processed — join side) and ``V`` the bytes actually transferred. The state is
non-clairvoyant: it needs no knowledge of the (unbounded) flow volume.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class FlowState(NamedTuple):
    """Arrays of shape [F] (MB / MB units). ``dt`` in seconds."""

    ls_t: jnp.ndarray    # L_f^s(t)       sender backlog at interval start
    lr_t: jnp.ndarray    # L_f^r(t)       receiver backlog at interval start
    v: jnp.ndarray       # V_f(t, t+dt)   bytes transferred in the interval
    ls_t1: jnp.ndarray   # L_f^s(t+dt)    sender backlog at interval end
    lr_t1: jnp.ndarray   # L_f^r(t+dt)    receiver backlog at interval end

    # ---- derived quantities used by Alg. 1 ---------------------------
    def uplink_demand(self) -> jnp.ndarray:
        """Predicted next-interval transfer demand w_f (numerator of eq. 3).

        Data generated in (t, t+dt) is V + (L^s(t+dt) − L^s(t)); if the
        generation rate holds, V + 2·L^s(t+dt) − L^s(t) must be moved in the
        next interval (paper §IV-B derivation).
        """
        return jnp.maximum(self.v + 2.0 * self.ls_t1 - self.ls_t, 0.0)

    def drain_rate(self, dt: float, eps: float = 1e-9) -> jnp.ndarray:
        """Receiver processing rate ρ_f (denominator of eq. 4):
        data processed in the interval = V − (L^r(t+dt) − L^r(t)), per second.
        """
        return jnp.maximum((self.v - self.lr_t1 + self.lr_t) / dt, eps)

    def any_backlog(self) -> jnp.ndarray:
        """Alg. 1 line 31 loop condition: some flow still has backlog."""
        return jnp.any((self.ls_t1 > 0.0) | (self.lr_t1 > 0.0))


def zeros(n_flows: int) -> FlowState:
    z = jnp.zeros((n_flows,), dtype=jnp.float32)
    return FlowState(z, z, z, z, z)
