"""Cross-layer collective-flow scheduler — the paper's technique applied to
the training fabric (DESIGN.md §2).

The paper allocates link bandwidth among a stream app's flows using
application-layer flow state. Here the "application" is the training step:
flows are the compiled program's collectives (DP reduce-scatters, TP
all-gathers, EP all-to-alls, DCN pod syncs), links are mesh-axis fabrics,
and flow state comes from the step's dataflow (gradient buckets *fork* onto
the DP axis as they become ready back-to-front; EP combines *join* on
expert outputs). There is no OpenFlow meter on a TPU — the allocator's rate
vector is enforced by *schedule shaping*: issue order, chunking, and
overlap windows for bucketed collectives.

Used by: launch-time analysis (examples/comm_schedule.py), the overlap
planner in §Perf, and the multi-job simulator.
"""
from __future__ import annotations

import dataclasses
import re

import jax.numpy as jnp
import numpy as np

from repro.core.allocator import OnlineAllocator
from repro.core.flowstate import FlowState
from repro.launch import hlo_stats
from repro.net.topology import LinkKind


@dataclasses.dataclass
class CollectiveFlow:
    name: str
    kind: str            # all-reduce | all-gather | ...
    bytes: float         # per-shard operand bytes
    axis: str            # mesh axis whose links it rides ("data"/"model"/"pod")
    phase: str = "grad"  # grad | weight | activation


_AXIS_BW_GBPS = {"model": 50.0, "data": 50.0, "pod": 6.25}


def extract_flows(hlo_text: str, mesh_axes: dict[str, int]) -> list[CollectiveFlow]:
    """Pull collective ops out of compiled HLO and attribute each to a mesh
    axis via its replica-group shape: contiguous groups (``<=[N]``) ride the
    minor (last) axis; strided groups (``T(...)``) ride a major axis."""
    flows: list[CollectiveFlow] = []
    axes = list(mesh_axes)
    for line in hlo_text.splitlines():
        m = hlo_stats._LINE_RE.search(line)
        if not m or m.group("variant") == "-done":
            continue
        kind = m.group("kind")
        rbytes = sum(hlo_stats.shape_bytes(d, s)
                     for d, s in hlo_stats._SHAPE_RE.findall(m.group("result")))
        g = hlo_stats._GROUPS_RE.search(line)
        gsize = int(g.group(2)) if g else 1
        if kind == "all-gather":
            rbytes //= max(gsize, 1)
        elif kind == "reduce-scatter":
            rbytes *= gsize
        # axis attribution
        strided = "T(" in line
        cands = [a for a in axes if mesh_axes[a] == gsize]
        if not cands:
            axis = axes[-1]
        elif len(cands) == 1:
            axis = cands[0]
        else:
            axis = cands[0] if strided else cands[-1]
        phase = ("grad" if "transpose" in line or "add" in line else
                 "activation")
        name_m = re.match(r"\s*%?([\w.\-]+)", line)
        flows.append(CollectiveFlow(
            name=name_m.group(1) if name_m else kind,
            kind=kind, bytes=float(rbytes), axis=axis, phase=phase))
    return flows


@dataclasses.dataclass
class CommSchedule:
    order: list[int]          # flow indices, highest urgency first
    rates: np.ndarray         # allocated share of axis bandwidth [F]
    chunks: list[int]         # chunk count per flow (overlap granularity)
    est_exposed_s: float      # comm time NOT hidden behind compute
    est_total_comm_s: float


def plan_schedule(
    flows: list[CollectiveFlow],
    mesh_axes: dict[str, int],
    step_compute_s: float,
    backlog_bytes: np.ndarray | None = None,
    min_chunk_bytes: float = 4e6,
) -> CommSchedule:
    """Run the paper's allocator over the collective flows.

    Each mesh axis is a link pair (fork onto the axis = uplink; join from
    the axis = downlink). Flow state: sender backlog = bytes ready to ship
    (gradient buckets accumulate back-to-front), receiver drain = the
    consumer's compute rate. The eq.(3)/(4) solves yield bandwidth shares;
    chunking spreads each flow across the overlap window ∝ its share.
    """
    F = len(flows)
    if F == 0:
        return CommSchedule([], np.zeros(0), [], 0.0, 0.0)
    axes = list(mesh_axes)
    L = len(axes)
    R = np.zeros((F, L))
    for i, f in enumerate(flows):
        R[i, axes.index(f.axis)] = 1.0
    caps = np.array([_AXIS_BW_GBPS[a] * 1e9 if a in _AXIS_BW_GBPS else 50e9
                     for a in axes])
    kinds = np.array([int(LinkKind.UPLINK)] * L)

    mb = np.array([f.bytes for f in flows])
    backlog = mb if backlog_bytes is None else backlog_bytes
    alloc = OnlineAllocator(R, caps, kinds, dt=max(step_compute_s, 1e-3))
    state = FlowState(
        ls_t=jnp.zeros(F), lr_t=jnp.zeros(F),
        v=jnp.asarray(mb, jnp.float32),
        ls_t1=jnp.asarray(backlog, jnp.float32),
        lr_t1=jnp.zeros(F),
    )
    rates = np.asarray(alloc(state))
    # urgency order: shortest remaining-transfer-time first (paper's min-max
    # objective ranks flows by w_f/x_f equalization — ties → largest first)
    ttime = backlog / np.maximum(rates, 1e-9)
    order = list(np.argsort(-ttime))
    chunks = [max(1, int(np.ceil(f.bytes / min_chunk_bytes))) for f in flows]

    per_axis_bytes = {a: sum(f.bytes for f in flows if f.axis == a)
                      for a in axes}
    comm_s = sum(b / (_AXIS_BW_GBPS[a] * 1e9)
                 for a, b in per_axis_bytes.items() if a in _AXIS_BW_GBPS)
    # overlap model: chunked flows hide behind compute except the last chunk
    # per axis + any comm beyond the compute window
    hidden = min(step_compute_s, comm_s)
    exposed = comm_s - hidden + sum(
        min_chunk_bytes / (_AXIS_BW_GBPS[f.axis] * 1e9)
        for f in flows if f.axis in _AXIS_BW_GBPS) / max(F, 1)
    return CommSchedule(order=order, rates=rates, chunks=chunks,
                        est_exposed_s=float(exposed),
                        est_total_comm_s=float(comm_s))
