"""App-aware online bandwidth allocation (paper §IV, Algorithm 1).

Pure-JAX, jittable, vectorized over links. Every ``dt`` the allocator maps the
observed :class:`repro.core.flowstate.FlowState` to a rate vector ``x`` [F]:

  1. per bottleneck *uplink* (Fork stage) solve eq. (3)
         min_x max_f w_f / x_f        s.t. Σ_f x_f = C_u,  x ≥ 0
     with w_f = V_f + 2 L_f^s(t+dt) − L_f^s(t). The min-max is attained when
     all transfer times w_f/x_f are equal → closed form x_f = C_u w_f / Σ w.

  2. per bottleneck *downlink* (Join stage) solve eq. (4)
         min_x max_f (L_f^r(t+dt) + x_f dt) / ρ_f     s.t. Σ_f x_f = C_d
     with ρ_f the receiver drain rate. Equalizing the queue-drain time θ
     gives the water-filling solution x_f = max(0, (θ ρ_f − L_f^r)/dt) with
     θ fixed by Σ_f x_f(θ) = C_d. Flows whose join partner is starved
     (small L^r, healthy ρ) get MORE bandwidth — the paper's stall-avoidance.

  3. x_f = min(x_f^u, x_f^d)  (Alg. 1 line 22);

  4. congested *internal* links scale their flows down proportionally and a
     flow takes the min across its links (lines 24–29);

  5. a backfill pass re-distributes leftover capacity proportionally to the
     previous pass's shares (§VI-C, link-utilization experiment).

The batched per-link solvers also exist as a Pallas TPU kernel
(``repro.kernels.waterfill``) — at datacenter scale (10⁴ links × 10³ flows
each interval) this is the allocator's compute hot-spot.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.flowstate import FlowState
from repro.net.topology import LinkKind

_EPS = 1e-9
_INF = jnp.inf

# Trace-time auto-chunk threshold for the sort solver's link axis: above
# 2x this many links, `allocate(block_links=None)` switches to
# `_per_link_rates_chunked` in blocks of this size (the [L, F] solver
# intermediates stop fitting in cache well before datacenter scale).
# Simulator topologies (L <= ~32) always stay on the single-pass form.
ALLOC_BLOCK_LINKS = 256


def solve_uplink(weights: jnp.ndarray, mask: jnp.ndarray, capacity) -> jnp.ndarray:
    """Eq. (3): proportional-to-demand allocation on one uplink.

    weights: [F] demand w_f (≥ 0); mask: [F] flows on this link; capacity: C_u.
    Returns x [F] with x·mask summing to C_u (if any flow is masked).
    """
    w = jnp.maximum(weights, 0.0) * mask
    total = jnp.sum(w)
    n = jnp.sum(mask)
    # all-zero demand: fall back to equal split (still work-conserving)
    w = jnp.where(total > _EPS, w, mask)
    total = jnp.where(total > _EPS, total, jnp.maximum(n, 1.0))
    return capacity * w / total


def solve_downlink(
    backlog: jnp.ndarray,
    rho: jnp.ndarray,
    mask: jnp.ndarray,
    capacity,
    dt: float,
) -> jnp.ndarray:
    """Eq. (4): equalize queue-drain times via exact water-filling (one sort).

    backlog: [F] L_f^r(t+dt); rho: [F] drain rates (>0); mask: [F]; C_d.

    θ solves Σ_f max(0, (θ ρ_f − L_f)/dt) = C. x_f(θ) is piecewise-linear,
    nondecreasing; flows activate at θ_f = L_f/ρ_f. Sorting by θ_f and
    scanning prefixes yields the unique consistent active set.
    """
    F = backlog.shape[0]
    rho = jnp.maximum(rho, _EPS)
    theta_act = jnp.where(mask > 0, backlog / rho, _INF)  # activation points
    order = jnp.argsort(theta_act)
    th_s = theta_act[order]
    rho_s = jnp.where(mask > 0, rho, 0.0)[order]
    L_s = jnp.where(mask > 0, backlog, 0.0)[order]
    cum_rho = jnp.cumsum(rho_s)
    cum_L = jnp.cumsum(L_s)
    # candidate θ for prefix of size k (index k-1)
    theta_k = (capacity * dt + cum_L) / jnp.maximum(cum_rho, _EPS)
    next_th = jnp.concatenate([th_s[1:], jnp.full((1,), _INF)])
    ks = jnp.arange(F)
    n_active = jnp.sum(mask).astype(jnp.int32)
    valid = (
        (theta_k >= th_s)
        & (theta_k <= next_th)
        & (ks < n_active)
        & jnp.isfinite(th_s)
    )
    # the unique valid prefix (fall back to the full active set)
    k_star = jnp.where(jnp.any(valid), jnp.argmax(valid), jnp.maximum(n_active - 1, 0))
    theta = theta_k[k_star]
    x = jnp.maximum(theta * rho - backlog, 0.0) / dt * mask
    # numerical cleanup: renormalize to the capacity exactly
    s = jnp.sum(x)
    x = jnp.where(s > _EPS, x * (capacity / s), x)
    return x


class LinkProgram(NamedTuple):
    """Static routing context for the allocator (from a Topology)."""

    R: jnp.ndarray          # [F, L] binary routing matrix
    capacity: jnp.ndarray   # [L]
    kind: jnp.ndarray       # [L] LinkKind values


def _per_link_rates_vmap(program: LinkProgram, state: FlowState, dt: float):
    """Reference path: vmap the per-link solvers across ALL links; select by
    link kind. One argsort *per link* — kept as the parity oracle for the
    fused solve below (and for the Pallas kernel's CPU cross-check)."""
    w_up = state.uplink_demand()
    rho = state.drain_rate(dt)
    L_r = state.lr_t1

    def one_link(r_col, cap, kind):
        mask = (r_col > 0).astype(w_up.dtype)
        x_u = solve_uplink(w_up, mask, cap)
        x_d = solve_downlink(L_r, rho, mask, cap, dt)
        return jnp.where(kind == int(LinkKind.DOWNLINK), x_d, x_u)

    # [L, F]
    return jax.vmap(one_link, in_axes=(1, 0, 0))(
        program.R, program.capacity, program.kind
    )


def _flow_sort_ctx(state: FlowState, dt: float):
    """Flow-axis preprocessing shared by every link of a solve: the
    per-flow inputs (demand w, backlog L^r, drain ρ) are the same for all
    links — only the on-link mask differs — so the downlink water-filling
    activation order ``θ_f = L_f/ρ_f`` is ONE global permutation, computed
    once (one argsort total, vs one per link in the vmap reference)."""
    rho = jnp.maximum(state.drain_rate(dt), _EPS)
    L_r = state.lr_t1
    theta_act = L_r / rho
    order = jnp.argsort(theta_act)
    return {
        "w_pos": jnp.maximum(state.uplink_demand(), 0.0),
        "rho": rho, "L_r": L_r, "order": order,
        "th_s": theta_act[order], "rho_s": rho[order], "L_s": L_r[order],
    }


def _solve_link_block(mask, cap, kind, ctx, dt: float):
    """Fused eqs. (3)/(4) for one [B_l, F] block of links against the
    shared flow context — the single source of the solver math for both
    the full-axis and the chunked paths.

    Per link, the prefix sums over its masked flows in global θ-order
    equal the prefix sums over its own sorted active set, so masked
    batched cumsums replace per-link sorts; the unique consistent active
    prefix (and the uplink proportional closed form) drop out of one
    [B_l, F] pass."""
    capc = cap[:, None]                                  # [B_l, 1]
    F = mask.shape[1]

    # ---- eq. (3): proportional-to-demand ------------------------------
    wm = ctx["w_pos"][None, :] * mask
    tot = jnp.sum(wm, axis=1, keepdims=True)
    n = jnp.sum(mask, axis=1, keepdims=True)
    wm = jnp.where(tot > _EPS, wm, mask)        # zero demand: equal split
    tot = jnp.where(tot > _EPS, tot, jnp.maximum(n, 1.0))
    x_up = capc * wm / tot

    # ---- eq. (4): batched prefix scans in global θ-order ---------------
    m_s = mask[:, ctx["order"]]                          # [B_l, F]
    cum_rho = jnp.cumsum(ctx["rho_s"][None, :] * m_s, axis=1)
    cum_L = jnp.cumsum(ctx["L_s"][None, :] * m_s, axis=1)
    theta_k = (capc * dt + cum_L) / jnp.maximum(cum_rho, _EPS)
    # active-set selection à la weighted simplex projection (Duchi et al.):
    # the consistent prefix is the LARGEST masked k whose candidate level
    # still covers its own activation point, θ_k ≥ θ̂_(k) — prefixes beyond
    # it would include flows that the candidate level cannot activate
    ks = jnp.arange(F)[None, :]
    ok = (m_s > 0) & (theta_k >= ctx["th_s"][None, :])
    k_star = jnp.max(jnp.where(ok, ks, 0), axis=1)       # [B_l]
    theta = jnp.take_along_axis(theta_k, k_star[:, None], axis=1)
    x_dn = jnp.maximum(theta * ctx["rho"][None, :] - ctx["L_r"][None, :],
                       0.0) / dt * mask
    s = jnp.sum(x_dn, axis=1, keepdims=True)
    x_dn = jnp.where(s > _EPS, x_dn * (capc / s), x_dn)

    is_down = (kind == int(LinkKind.DOWNLINK))[:, None]
    return jnp.where(is_down, x_dn, x_up)


def _per_link_rates(program: LinkProgram, state: FlowState, dt: float):
    """Fused batched [L, F] solve of eqs. (3) and (4) for every link at
    once: one global argsort (:func:`_flow_sort_ctx`) + one
    :func:`_solve_link_block` pass over the full link axis."""
    mask = (program.R.T > 0).astype(jnp.float32)         # [L, F]
    return _solve_link_block(mask, program.capacity, program.kind,
                             _flow_sort_ctx(state, dt), dt)


def _per_link_rates_chunked(program: LinkProgram, state: FlowState,
                            dt: float, block_links: int):
    """Chunked-links variant of the fused solve: the same
    :func:`_solve_link_block` math, but the link axis is processed in
    ``block_links`` chunks under ``lax.map`` (sequential), so the [L, F]
    intermediates (masked cumsums, candidate levels, prefix selections)
    are capped at [block_links, F] — at 10⁴ links × 10³ flows that's the
    difference between ~40 MB per intermediate and ~4 MB total working
    set. Only the [L, F] *output* (and the input routing matrix) stay
    full-size. The flow context (one global argsort) is shared across
    chunks, exactly as in the fused form.
    """
    L, F = program.R.shape[1], program.R.shape[0]
    ctx = _flow_sort_ctx(state, dt)

    def chunk(args):
        mask, cap, kind = args                      # [blk, F], [blk], [blk]
        return _solve_link_block(mask, cap, kind, ctx, dt)

    blk = max(int(block_links), 1)
    n_chunks = -(-L // blk)
    pad = n_chunks * blk - L
    # padded links: empty mask, INTERNAL kind -> all-zero rows, dropped below
    maskT = jnp.pad((program.R.T > 0).astype(jnp.float32), ((0, pad), (0, 0)))
    cap_p = jnp.pad(program.capacity, (0, pad))
    kind_p = jnp.pad(program.kind, (0, pad),
                     constant_values=int(LinkKind.INTERNAL))
    rows = jax.lax.map(chunk, (maskT.reshape(n_chunks, blk, F),
                               cap_p.reshape(n_chunks, blk),
                               kind_p.reshape(n_chunks, blk)))
    return rows.reshape(n_chunks * blk, F)[:L]


def _per_link_rates_pallas(program: LinkProgram, state: FlowState, dt: float):
    """Same [L, F] solve through the batched Pallas waterfill kernel
    (``repro.kernels.waterfill``) — bisection on θ instead of the sort.

    The per-flow state ships as [F] vectors (``waterfill_flows``); only the
    on-link mask is [L, F], so no dense per-link broadcasts of w/backlog/ρ
    are materialized. INTERNAL links are fed as uplinks; ``allocate`` never
    reads their rows (it handles internal links by proportional
    scale-down), so only the UPLINK/DOWNLINK selection has to agree with
    the exact solvers.
    """
    from repro.kernels.waterfill.ops import waterfill_flows  # avoids cycle

    mask = (program.R.T > 0).astype(jnp.float32)          # [L, F]
    kind01 = (program.kind == int(LinkKind.DOWNLINK)).astype(jnp.int32)
    # bigger link blocks at scale keep the grid small (10⁴ links / 128 =
    # 79 programs); tiny programs keep the padding overhead low below that.
    # The flow axis walks in 256-lane chunks once F outgrows one chunk, so
    # F = 10³–10⁴ never runs its reductions over one giant lane block.
    L, F = mask.shape
    block_links = 8 if L <= 512 else 128
    block_flows = None if F <= 256 else 256
    return waterfill_flows(
        state.uplink_demand(), state.lr_t1, state.drain_rate(dt),
        mask, program.capacity, kind01, dt=dt, block_links=block_links,
        block_flows=block_flows)


def backfill(x: jnp.ndarray, program: LinkProgram, iters: int = 8,
             damping: float = 0.9) -> jnp.ndarray:
    """§VI-C backfill: hand leftover link capacity to flows proportionally to
    their share from the previous pass, never violating any link.

    A flow's headroom min over its links of ``x_f·resid_l/load_l`` factors as
    ``x_f · min_l(resid_l/load_l)`` (x ≥ 0), so each iteration reduces to one
    [L] residual-ratio vector and one masked min — the [F, L] ``share`` and
    ``gain`` intermediates of the naive form are never materialized.
    """
    R, cap = program.R, program.capacity
    on_link = R > 0
    on_net = jnp.sum(R, axis=1) > 0  # flows that traverse ≥1 link

    def body(_, x):
        load = x @ R                                   # [L]
        ratio = jnp.maximum(cap - load, 0.0) / jnp.maximum(load, _EPS)
        r_min = jnp.min(jnp.where(on_link, ratio[None, :], _INF), axis=1)
        inc = jnp.where(on_net & jnp.isfinite(r_min), x * r_min, 0.0)
        return x + damping * inc

    return jax.lax.fori_loop(0, iters, body, x)


@functools.partial(jax.jit, static_argnames=("dt", "backfill_iters", "solver",
                                             "block_links"))
def allocate(
    program: LinkProgram,
    state: FlowState,
    dt: float = 1.0,
    backfill_iters: int = 8,
    solver: str = "sort",
    block_links: int | None = None,
) -> jnp.ndarray:
    """Algorithm 1, one interval: FlowState -> rate vector x [F] (MB/s).

    solver: "sort" — exact sort-based per-link solves (CPU-friendly);
            "pallas" — the batched bisection waterfill kernel (TPU-friendly;
            interpret mode off-TPU). Both satisfy the same KKT conditions.
    block_links: with the "sort" solver, process links in chunks of this
            size (sequential ``lax.map``), capping the [L, F] solver
            intermediates — exact same results, bounded working set at
            datacenter link counts (ignored by "pallas", which tiles
            internally). ``None`` (the default) dispatches at trace time
            on the static link count: single-pass below
            ``2 * ALLOC_BLOCK_LINKS`` links (every simulator topology —
            the fused form's XLA program is unchanged there), chunks of
            ``ALLOC_BLOCK_LINKS`` above it. Pass ``0`` to force the
            single-pass form at any size.
    """
    if solver == "sort":
        if block_links is None and program.R.shape[1] > 2 * ALLOC_BLOCK_LINKS:
            block_links = ALLOC_BLOCK_LINKS
        if block_links:
            per_link = _per_link_rates_chunked(program, state, dt,
                                               block_links)   # [L, F]
        else:
            per_link = _per_link_rates(program, state, dt)     # [L, F]
    elif solver == "pallas":
        per_link = _per_link_rates_pallas(program, state, dt)  # [L, F]
    else:
        raise ValueError(f"unknown solver {solver!r}")
    kind = program.kind

    # Alg. 1 line 22 collapsed: min(x^u, x^d) over a flow's links is the min
    # of per_link over its non-internal links (each row already carries the
    # kind-appropriate solve), so one masked reduction replaces the two
    # per-kind passes.
    sel = (kind != int(LinkKind.INTERNAL))[:, None] & (program.R.T > 0)
    x = jnp.min(jnp.where(sel, per_link, _INF), axis=0)
    x = jnp.where(jnp.isfinite(x), x, 0.0)     # flows with no links: handled by caller

    # Internal links: proportional scale-down, min across links (lines 24-29)
    load = x @ program.R                                       # [L]
    is_int = kind == int(LinkKind.INTERNAL)
    scale_l = jnp.where(
        is_int & (load > program.capacity),
        program.capacity / jnp.maximum(load, _EPS),
        1.0,
    )
    per_flow_scale = jnp.where(
        (program.R > 0) & is_int[None, :], scale_l[None, :], 1.0
    ).min(axis=1)
    x = x * per_flow_scale

    if backfill_iters:
        x = backfill(x, program, iters=backfill_iters)
    return x


class OnlineAllocator:
    """Alg. 1 driver: wraps a static LinkProgram; call once per Δt."""

    def __init__(self, R, capacity, kind, dt: float = 1.0,
                 backfill_iters: int = 8, solver: str = "sort"):
        self.program = LinkProgram(
            R=jnp.asarray(R, jnp.float32),
            capacity=jnp.asarray(capacity, jnp.float32),
            kind=jnp.asarray(kind, jnp.int32),
        )
        self.dt = float(dt)
        self.backfill_iters = int(backfill_iters)
        self.solver = solver

    def __call__(self, state: FlowState) -> jnp.ndarray:
        return allocate(self.program, state, dt=self.dt,
                        backfill_iters=self.backfill_iters, solver=self.solver)

    @classmethod
    def from_topology(cls, topo, flows, **kw) -> "OnlineAllocator":
        return cls(
            topo.routing_matrix(flows), topo.capacities, topo.link_kinds, **kw
        )
