# The paper's primary contribution: application-aware, online, dynamic
# bandwidth allocation (Alg. 1 + §VII multi-app fairness), as composable
# JAX modules. Substrates live in sibling subpackages (net/, streams/,
# models/, train/, serve/, kernels/).
from repro.core.flowstate import FlowState, zeros as flowstate_zeros  # noqa: F401
from repro.core.allocator import (  # noqa: F401
    LinkProgram,
    OnlineAllocator,
    allocate,
    backfill,
    solve_downlink,
    solve_uplink,
)
from repro.core.tcp import (  # noqa: F401
    demand_limited_maxmin,       # while-loop parity oracle
    demand_limited_maxmin_np,    # sequential numpy reference
    maxmin_fused,                # the hot-path fixed-trip solver
    maxmin_fused_step,           # order-cached per-tick variant
    maxmin_order_init,           # its initial scan carry
    maxmin_rates,                # while-loop parity oracle
)
from repro.core.multiapp import (  # noqa: F401
    AppFairScheduler,
    ewma_throughput,
    group_by_throughput,
    jain_index,
    strict_priority_alloc,
)
