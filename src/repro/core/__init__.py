# The paper's primary contribution: application-aware, online, dynamic
# bandwidth allocation (Alg. 1 + §VII multi-app fairness), as composable
# JAX modules. Substrates live in sibling subpackages (net/, streams/,
# models/, train/, serve/, kernels/).
from repro.core.flowstate import FlowState, zeros as flowstate_zeros  # noqa: F401
from repro.core.allocator import (  # noqa: F401
    LinkProgram,
    OnlineAllocator,
    allocate,
    backfill,
    solve_downlink,
    solve_uplink,
)
from repro.core.tcp import demand_limited_maxmin, maxmin_rates  # noqa: F401
from repro.core.multiapp import (  # noqa: F401
    AppFairScheduler,
    ewma_throughput,
    group_by_throughput,
    jain_index,
    strict_priority_alloc,
)
