"""Multi-application bandwidth sharing & application-level fairness (§VII).

TCP's flow-level fairness hands an app with many flows a proportionally large
slice of each bottleneck. The paper's `App-Fair` point solution:

  * track per-app throughput with the EWMA of eq. (5):
        μ_i(t+Δt) = α μ_i(t) + (1−α) μ_i(Δt)
  * cluster apps by μ into priority groups (lowest throughput → highest
    priority), at most ``m`` groups (m = 8 queues in the paper's switches);
  * strict-priority allocation: fill group by group with max-min inside a
    group; displacement between groups every interval avoids starvation;
  * measured with the Jain fairness index (paper: 0.98–0.99 vs TCP 0.84).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tcp import maxmin_fused, maxmin_rates

_EPS = 1e-9


def ewma_throughput(mu_t, mu_dt, alpha: float):
    """Eq. (5)."""
    return alpha * mu_t + (1.0 - alpha) * mu_dt


def jain_index(x: jnp.ndarray) -> jnp.ndarray:
    """Jain, Chiu & Hawe fairness index: (Σx)² / (n Σx²) ∈ (0, 1]."""
    n = x.shape[0]
    return jnp.sum(x) ** 2 / jnp.maximum(n * jnp.sum(x * x), _EPS)


def group_by_throughput(mu: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """'Simple clustering': rank apps by EWMA throughput and split into
    ``n_groups`` quantile buckets. Returns priority per app — 0 is HIGHEST
    (lowest achieved throughput), as in the paper."""
    n_apps = mu.shape[0]
    rank = jnp.argsort(jnp.argsort(mu))          # 0 = lowest throughput
    per = -(-n_apps // n_groups)                 # ceil
    return jnp.minimum(rank // per, n_groups - 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_groups",))
def strict_priority_alloc(
    R: jnp.ndarray,            # [F, L]
    capacity: jnp.ndarray,     # [L]
    app_of_flow: jnp.ndarray,  # [F] int app ids
    app_priority: jnp.ndarray, # [A] 0 = highest
    n_groups: int = 8,
) -> jnp.ndarray:
    """Multi-level strict-priority scheduler: per priority level (high→low)
    run max-min among that level's flows on the residual capacity.

    Uses the fused fixed-trip solver (`maxmin_fused`) with an
    always-slack demand cap (no single flow can exceed the total network
    capacity), so — like the tcp policy — the appfair hot path contains no
    data-dependent ``lax.while_loop``: a level's flows that cross no
    congested link get the slack cap, exactly where the while-loop oracle
    returned +inf (both are clamped by the caller's link mask)."""
    F, L = R.shape
    prio_of_flow = app_priority[app_of_flow]
    x = jnp.zeros((F,), R.dtype)
    on_net = jnp.sum(R, axis=1) > 0
    # any on-net flow's rate is bounded by the largest link it crosses, so
    # the total capacity is a demand cap that never binds below saturation
    cap_bound = jnp.sum(capacity) + 1.0

    def level(p, x):
        used = jnp.sum(R * x[:, None], axis=0)
        resid = jnp.maximum(capacity - used, 0.0)
        sel = prio_of_flow == p
        demand = jnp.where(sel & on_net, cap_bound, 0.0)
        rates = maxmin_fused(R, resid, demand)
        return x + rates * sel.astype(R.dtype)

    return jax.lax.fori_loop(0, n_groups, level, x)


class AppFairState(NamedTuple):
    total: jnp.ndarray     # [A] cumulative throughput per app
    n: jnp.ndarray         # [] intervals observed
    priority: jnp.ndarray  # [A]


class AppFairScheduler:
    """§VII App-Fair: blend the cumulative average μ(t) ('achieved average
    throughput up to time t') with the recent interval μ(Δt) via eq. (5),
    regroup every interval (displacement), allocate with strict priority."""

    def __init__(self, n_apps: int, alpha: float = 0.5, n_groups: int = 8):
        self.alpha = float(alpha)
        self.n_groups = int(n_groups)
        self.n_apps = int(n_apps)

    def init(self) -> AppFairState:
        return AppFairState(
            total=jnp.zeros((self.n_apps,), jnp.float32),
            n=jnp.zeros((), jnp.float32),
            priority=jnp.zeros((self.n_apps,), jnp.int32),
        )

    def step(
        self,
        state: AppFairState,
        mu_interval: jnp.ndarray,   # [A] throughput achieved this Δt
        R: jnp.ndarray,
        capacity: jnp.ndarray,
        app_of_flow: jnp.ndarray,
    ) -> tuple[AppFairState, jnp.ndarray]:
        total = state.total + mu_interval
        n = state.n + 1.0
        mu_hist = total / jnp.maximum(n, 1.0)  # μ(t): running average
        mu = ewma_throughput(mu_hist, mu_interval, self.alpha)
        # displacement: regrouping *every interval* moves apps between groups,
        # guaranteeing no app is starved indefinitely (paper §VII).
        prio = group_by_throughput(mu, self.n_groups)
        x = strict_priority_alloc(
            R, capacity, app_of_flow, prio, n_groups=self.n_groups
        )
        return AppFairState(total=total, n=n, priority=prio), x


def tcp_app_throughput(R, capacity, app_of_flow, n_apps: int):
    """Baseline for Fig. 13: per-app aggregate of flow-level max-min rates."""
    x = maxmin_rates(R, capacity)
    x = jnp.where(jnp.isfinite(x), x, 0.0)
    return jax.ops.segment_sum(x, app_of_flow, num_segments=n_apps)
