"""Suite-wide hang protection: a faulthandler-based ``pytest-timeout``
equivalent (the container has no pytest-timeout wheel, and the tier-1
suite now includes resilience tests that *deliberately* hang a transfer
worker — a regression there must fail with a stack trace, not wedge CI).

Two layers per test, both configured by ``REPRO_TEST_TIMEOUT_S`` (default
600 s, generous against cold-compile tests on a loaded container; ``0``
disables):

* a ``SIGALRM`` timer that raises a pytest failure *inside* the test on
  expiry — the traceback shows exactly where the test was stuck and the
  rest of the suite keeps running;
* a ``faulthandler.dump_traceback_later`` backstop at 2× the budget that
  dumps every thread's stack and hard-exits — for the case where the main
  thread itself is wedged in non-interruptible C code (a jitted XLA call,
  a hung ``device_put``) and the Python-level signal handler never runs.

POSIX-only (SIGALRM); on other platforms the guard is a no-op. Tests may
override their budget with ``@pytest.mark.timeout_s(30)``.
"""
from __future__ import annotations

import faulthandler
import os
import signal

import pytest

DEFAULT_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT_S", "600"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout_s(seconds): per-test wall-clock budget enforced by the "
        "SIGALRM hang guard (see tests/conftest.py)")


@pytest.fixture(autouse=True)
def _hang_guard(request):
    budget = DEFAULT_TIMEOUT_S
    marker = request.node.get_closest_marker("timeout_s")
    if marker is not None:
        budget = float(marker.args[0])
    if budget <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def _on_alarm(signum, frame):
        pytest.fail(f"test exceeded its {budget:g}s wall-clock budget "
                    f"(hang guard; raise with @pytest.mark.timeout_s or "
                    f"REPRO_TEST_TIMEOUT_S)")

    old_handler = signal.signal(signal.SIGALRM, _on_alarm)
    signal.setitimer(signal.ITIMER_REAL, budget)
    faulthandler.dump_traceback_later(budget * 2, exit=True)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old_handler)
