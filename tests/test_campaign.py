"""Streaming campaign runtime (PR 7).

The contract under test: ``FleetRunner.run_campaign`` streams an
arbitrarily large scenario list through fixed-shape chunks and its metrics
are **bitwise-identical** to the materialized ``run`` path on the same
scenarios — chunking, triple-buffered staging, the three-stage pipeline's
prefetching transfer worker, and fetching only the on-device epilogue
change *where* bytes live, never a single bit of *what* is computed.
Plus: one compiled executable per bucket however many chunks stream
through it, host staging bounded by the three rotating slots per stream,
the ``fingerprint`` staging knob (content / identity / off), opt-in
trajectory retention, the pipeline timing stats (components sum to ≤ wall
time; ``overlap_fraction`` well-defined for single-chunk campaigns), the
``chunk_rows="auto"`` backend calibration, and the
epilogue-vs-host-property consistency contract. Multi-device sharding of
the chunk stream is covered by ``test_multidevice.py``.
"""
import dataclasses

import numpy as np
import pytest

import repro.streams.fleet as fleet_mod
from repro.streams import (
    CAMPAIGN_METRICS,
    FleetRunner,
    campaign_fleet,
    compile_fleet,
    link_failure_sweep,
    simulate,
)

SECONDS = 10.0
DT = 0.5


@pytest.fixture(scope="module")
def corpus():
    """256-scenario streaming corpus: {TT, TI} x capacity grid x
    {static, in-run failure, in-run diurnal}, 6 distinct shapes."""
    sims = compile_fleet(campaign_fleet(256, seed=0))
    assert len(sims) == 256
    shapes = {dataclasses.astuple(fleet_mod._sim_shape(s)) for s in sims}
    assert len(shapes) == 6
    # static and scheduled scenarios interleave in index order, so chunk
    # boundaries straddle mixed static/scheduled members by construction
    dyn = [s.is_dynamic for s in sims]
    assert any(dyn) and not all(dyn)
    return sims


@pytest.fixture(scope="module")
def corpus_xf(corpus):
    rng = np.random.default_rng(7)
    return [rng.uniform(0.2, 3.0, s.R.shape[0]).astype(np.float32)
            for s in corpus]


def _materialized_metrics(runner, sims, policy, **kw):
    res = runner.run(sims, policy, seconds=SECONDS, dt=DT, **kw)
    return np.stack([r.metrics for r in res])


class TestStreamingParity:
    """Streamed metrics == materialized metrics, bit for bit."""

    @pytest.mark.parametrize("policy", ["tcp", "appaware", "appfair",
                                        "fixed"])
    def test_bitwise_vs_materialized(self, corpus, corpus_xf, policy):
        kw = dict(x_fixed=corpus_xf) if policy == "fixed" else {}
        runner = FleetRunner()
        cr = runner.run_campaign(corpus, policy, seconds=SECONDS, dt=DT,
                                 chunk_rows=32, **kw)
        stats = runner.last_stats
        assert stats["n_chunks"] > stats["n_buckets"]  # actually chunked
        oracle = _materialized_metrics(FleetRunner(), corpus, policy, **kw)
        np.testing.assert_array_equal(cr.metrics, oracle)
        assert np.isfinite(cr.metrics[:, :5]).all()  # recovery may be inf
        assert cr.metrics.shape == (len(corpus), len(CAMPAIGN_METRICS))

    def test_single_scenario_simulate_agrees(self, corpus):
        # the campaign row for a scenario equals its standalone simulate()
        # metrics — one epilogue definition end to end. Tolerance, not
        # bitwise: the standalone path is unpadded and padding
        # re-associates XLA reductions (same contract as
        # test_packed_fleet's per-scenario parity class).
        runner = FleetRunner()
        cr = runner.run_campaign(corpus[:16], "tcp", seconds=SECONDS,
                                 dt=DT, chunk_rows=8)
        one = simulate(corpus[0], "tcp", seconds=SECONDS, dt=DT)
        np.testing.assert_allclose(cr.metrics[0], one.metrics, rtol=1e-5,
                                   atol=1e-7)

    def test_metric_accessors(self, corpus):
        runner = FleetRunner()
        cr = runner.run_campaign(corpus[:16], "tcp", seconds=SECONDS,
                                 dt=DT, chunk_rows=8)
        np.testing.assert_array_equal(
            cr.throughput_tps,
            cr.metric("avg_tput_mb_s") * cr.tuples_per_mb)
        assert cr.avg_latency_s.shape == (16,)
        assert (cr.utilization >= 0).all()


class TestChunkReuse:
    """Every chunk of a bucket rides ONE compiled executable."""

    def test_no_recompile_across_chunks(self, corpus):
        runner = FleetRunner()
        runner.run_campaign(corpus[:96], "tcp", seconds=SECONDS, dt=DT,
                            chunk_rows=16)
        stats = runner.last_stats
        assert stats["n_chunks"] > stats["n_buckets"]
        # one executable per bucket, regardless of how many chunks each
        # bucket streamed — the cache would grow per chunk otherwise
        assert runner.compile_cache_size() == stats["n_buckets"]
        # warm repeat: zero new compilations, bitwise-stable metrics
        n0 = runner.compile_cache_size()
        a = runner.run_campaign(corpus[:96], "tcp", seconds=SECONDS, dt=DT,
                                chunk_rows=16)
        b = runner.run_campaign(corpus[:96], "tcp", seconds=SECONDS, dt=DT,
                                chunk_rows=16)
        assert runner.compile_cache_size() == n0
        np.testing.assert_array_equal(a.metrics, b.metrics)

    def test_bounded_staging_2048(self):
        # the acceptance-scale campaign: 10^3-scenario class, host staging
        # bounded by the three rotating chunk slots per stream (one per
        # pipeline stage), short horizon (the bound is about memory, not
        # ticks)
        sims = compile_fleet(campaign_fleet(2048, seed=1))
        runner = FleetRunner()
        cr = runner.run_campaign(sims, "tcp", seconds=4.0, dt=DT)
        stats = runner.last_stats
        assert cr.metrics.shape[0] == 2048
        assert np.isfinite(cr.metrics[:, :4]).all()
        bound = 3 * stats["chunk_rows"] * stats["n_streams"]
        assert stats["peak_staged_rows"] <= bound
        assert stats["peak_staged_rows"] <= 3 * 64 * stats["n_streams"]
        assert stats["peak_staged_bytes"] > 0
        assert stats["n_chunks"] >= 2048 // 64
        assert runner.compile_cache_size() == stats["n_buckets"]

    def test_chunk_rows_validation(self, corpus):
        with pytest.raises(ValueError):
            FleetRunner().run_campaign(corpus[:4], chunk_rows=0)
        with pytest.raises(ValueError):
            FleetRunner().run_campaign(corpus[:4], chunk_rows="adaptive")
        with pytest.raises(ValueError):
            FleetRunner().run_campaign([])


class TestPipelineStats:
    """Campaign timing accounting: ``transfer_s`` is its own stat, the
    components never exceed wall time, and ``overlap_fraction`` is
    well-defined (== 1.0) for single-chunk campaigns."""

    def test_components_sum_le_wall(self, corpus):
        runner = FleetRunner()
        runner.run_campaign(corpus[:96], "tcp", seconds=SECONDS, dt=DT,
                            chunk_rows=16)
        st = dict(runner.last_stats)
        for key in ("stage_s", "dispatch_s", "block_s", "transfer_s",
                    "transfer_wait_s", "wall_s"):
            assert st[key] >= 0.0, key
        # dispatch-thread components: staging, waiting on the prefetched
        # copy, dispatch, and metric-fetch blocking all happen serially on
        # the dispatch thread, so they must fit inside the wall clock.
        # transfer_s itself rides the worker thread and may overlap any
        # of them — it is excluded from the sum on purpose.
        spent = (st["stage_s"] + st["transfer_wait_s"] + st["dispatch_s"]
                 + st["block_s"])
        assert spent <= st["wall_s"] + 1e-6
        assert 0.0 <= st["overlap_fraction"] <= 1.0
        assert 0.0 <= st["transfer_overlap"] <= 1.0

    def test_single_chunk_overlap_well_defined(self, corpus):
        # one bucket, one chunk: nothing is hideable (no compute is ever
        # in flight while staging), so overlap_fraction reports the
        # vacuous 1.0 instead of a misleading 0/0
        sims = [s for s in corpus[:32]
                if fleet_mod._sim_shape(s) == fleet_mod._sim_shape(
                    corpus[0])][:6]
        assert len(sims) >= 2
        runner = FleetRunner()
        runner.run_campaign(sims, "tcp", seconds=SECONDS, dt=DT,
                            chunk_rows=64)
        st = runner.last_stats
        assert st["n_chunks"] == 1
        assert st["overlap_fraction"] == 1.0

    def test_transfer_stats_present(self, corpus):
        runner = FleetRunner()
        runner.run_campaign(corpus[:64], "tcp", seconds=SECONDS, dt=DT,
                            chunk_rows=16)
        st = runner.last_stats
        assert st["transfer_s"] > 0.0
        assert st["transfer_wait_s"] >= 0.0
        assert st["n_streams"] >= 1
        assert len(st["target_chunk_rows"]) == st["n_buckets"]


class TestAutoChunk:
    """``chunk_rows="auto"``: per-backend calibration drives the chunk
    size; the calibration is measured once per process and recorded in
    ``last_stats``."""

    def test_auto_runs_and_records_calibration(self, corpus):
        runner = FleetRunner()
        cr = runner.run_campaign(corpus[:48], "tcp", seconds=SECONDS,
                                 dt=DT, chunk_rows="auto")
        st = dict(runner.last_stats)
        assert cr.metrics.shape[0] == 48
        assert st["auto_chunk"] is True
        cal = st["calibration"]
        assert cal["backend"] == "cpu"
        assert cal["dispatch_us"] > 0 and cal["sync_us"] > 0
        assert cal["proxy_mflops"] > 0
        lo, hi = fleet_mod._CALIB_CLAMP.get(
            cal["backend"], fleet_mod._CALIB_CLAMP_DEFAULT)
        assert lo <= cal["tick_overhead_flops"] <= hi
        for t in st["target_chunk_rows"]:
            assert (fleet_mod.AUTO_CHUNK_MIN <= t
                    <= fleet_mod.AUTO_CHUNK_MAX)

    def test_auto_matches_materialized(self, corpus):
        runner = FleetRunner()
        cr = runner.run_campaign(corpus[:48], "tcp", seconds=SECONDS,
                                 dt=DT, chunk_rows="auto")
        oracle = _materialized_metrics(FleetRunner(), corpus[:48], "tcp")
        np.testing.assert_array_equal(cr.metrics, oracle)

    def test_calibration_cached_per_process(self):
        a = fleet_mod.calibrate_backend()
        b = fleet_mod.calibrate_backend()
        assert a is b
        assert fleet_mod._default_tick_overhead() == a.tick_overhead_flops


class TestFingerprintKnob:
    """`fingerprint="content"|"identity"|"off"` on FleetRunner."""

    def test_default_is_content_and_invalid_rejected(self):
        assert FleetRunner().fingerprint == "content"
        with pytest.raises(ValueError):
            FleetRunner(fingerprint="sha")

    def test_identity_skips_hashing_content_does_not(self, corpus,
                                                     monkeypatch):
        calls = {"n": 0}
        orig = fleet_mod._sim_content_sig

        def counting(sim):
            calls["n"] += 1
            return orig(sim)

        monkeypatch.setattr(fleet_mod, "_sim_content_sig", counting)
        sims = corpus[:16]
        ident = FleetRunner(fingerprint="identity")
        a = ident.run(sims, "tcp", seconds=SECONDS, dt=DT)
        b = ident.run(sims, "tcp", seconds=SECONDS, dt=DT)
        assert calls["n"] == 0  # identity mode never hashes
        content = FleetRunner()  # default warm path: unchanged, hashes
        c = content.run(sims, "tcp", seconds=SECONDS, dt=DT)
        d = content.run(sims, "tcp", seconds=SECONDS, dt=DT)
        assert calls["n"] > 0
        for ra, rb, rc, rd in zip(a, b, c, d):
            np.testing.assert_array_equal(ra.sink_mb, rb.sink_mb)
            np.testing.assert_array_equal(ra.sink_mb, rc.sink_mb)
            np.testing.assert_array_equal(rc.sink_mb, rd.sink_mb)

    def test_off_restages_every_call(self, corpus):
        off = FleetRunner(fingerprint="off")
        sims = corpus[:16]
        a = off.run(sims, "tcp", seconds=SECONDS, dt=DT)
        assert off._filled  # staged, but never consulted for reuse
        b = off.run(sims, "tcp", seconds=SECONDS, dt=DT)
        for ra, rb in zip(a, b):
            np.testing.assert_array_equal(ra.sink_mb, rb.sink_mb)

    def test_streaming_path_never_hashes(self, corpus, monkeypatch):
        def boom(sim):  # any hash on the streaming path is a bug
            raise AssertionError("campaign path must not content-hash")

        monkeypatch.setattr(fleet_mod, "_sim_content_sig", boom)
        runner = FleetRunner()  # default content mode
        cr = runner.run_campaign(corpus[:32], "tcp", seconds=SECONDS,
                                 dt=DT, chunk_rows=16)
        assert cr.metrics.shape[0] == 32


class TestRetainTrajectories:
    def test_opt_in_matches_materialized(self, corpus):
        sims = corpus[:24]
        runner = FleetRunner()
        cr = runner.run_campaign(sims, "tcp", seconds=SECONDS, dt=DT,
                                 chunk_rows=8, retain_trajectories=True)
        assert cr.results is not None and len(cr.results) == 24
        oracle = FleetRunner().run(sims, "tcp", seconds=SECONDS, dt=DT)
        for r, o in zip(cr.results, oracle):
            np.testing.assert_array_equal(r.sink_mb, o.sink_mb)
            np.testing.assert_array_equal(r.latency, o.latency)
            np.testing.assert_array_equal(r.link_load, o.link_load)
            # trajectories are bitwise; the epilogue's reductions may
            # re-associate at these tiny batch sizes (8-row chunks vs the
            # 24-row materialized bucket lower differently), so the metric
            # leaf gets the 1-ULP band here — the bitwise metric contract
            # is pinned at campaign scale by TestStreamingParity
            np.testing.assert_allclose(r.metrics, o.metrics, rtol=1e-6)
            if o.caps_t is not None:
                np.testing.assert_array_equal(r.caps_t, o.caps_t)

    def test_default_retains_nothing(self, corpus):
        cr = FleetRunner().run_campaign(corpus[:8], "tcp", seconds=SECONDS,
                                        dt=DT)
        assert cr.results is None


class TestEpilogueConsistency:
    """The on-device epilogue mirrors the host-side SimResult properties
    (same definitions, float32 in-program vs float64 host — so this is the
    tolerance contract; bitwise equality is the streamed-vs-materialized
    axis, tested above)."""

    def test_matches_host_properties(self):
        scen = link_failure_sweep(n=1, seed=3, in_run=True,
                                  t_fail=60.0, t_recover=90.0)[0]
        sim = scen.compile()
        r = simulate(sim, "tcp", seconds=120.0, dt=DT, t_event=60.0)
        m = r.metric
        assert m("avg_tput_mb_s") * sim.tuples_per_mb == pytest.approx(
            r.throughput_tps, rel=1e-4)
        assert m("avg_latency_s") == pytest.approx(r.avg_latency_s,
                                                   rel=1e-4)
        assert m("utilization") == pytest.approx(
            r.bottleneck_utilization(), rel=1e-4)
        assert m("dip_depth") == pytest.approx(r.dip_depth(60.0), abs=1e-3)
        assert m("total_sink_mb") == pytest.approx(float(r.sink_mb.sum()),
                                                   rel=1e-4)
        host_rec = r.recovery_time_s(60.0)
        dev_rec = m("recovery_time_s")
        if np.isinf(host_rec):
            assert np.isinf(dev_rec)
        else:
            # float32 band-edge ties may shift the settling tick by one
            assert abs(dev_rec - host_rec) <= 2 * DT

    def test_metrics_without_epilogue_raises(self):
        from repro.streams.simulator import SimResult
        r = SimResult(
            sink_mb=np.zeros(4), sink_mb_app=np.zeros((4, 1)),
            latency=np.zeros(4), link_load=np.zeros((4, 2)),
            caps=np.ones(2), kinds=np.zeros(2, int),
            tuples_per_mb=1.0, dt=DT)
        with pytest.raises(ValueError):
            r.metric("utilization")


class TestEmitValidation:
    """benchmarks.common.emit rejects fake timings (satellite of the
    fleet_order_cache us_per_call=0.0 fix)."""

    def test_rejects_nonpositive_and_allows_absent(self, tmp_path,
                                                   monkeypatch, capsys):
        common = pytest.importorskip("benchmarks.common")
        monkeypatch.setenv("BENCH_DIR", str(tmp_path))
        with pytest.raises(ValueError):
            common.emit([{"name": "x", "us_per_call": 0.0}], "scratch")
        with pytest.raises(ValueError):
            common.emit([{"name": "x", "us_per_call": -3.0}], "scratch")
        common.emit([{"name": "y", "jain": 0.9}], "scratch")
        assert "y,-," in capsys.readouterr().out
        common.emit([{"name": "z", "us_per_call": 12.5}], "scratch")
        assert "z,12.50," in capsys.readouterr().out
