"""Batched multi-scenario engine: padding neutrality, batch-vs-sequential
parity over the ≥16-scenario seed fleet, and the deterministic end-to-end
regression on the paper's seed workloads (TT / junction-heavy TI)."""
import numpy as np
import pytest

from repro.net import big_switch
from repro.streams import (
    FleetShape,
    compile_fleet,
    compile_sim,
    pad_sim,
    parallelize,
    round_robin,
    seed_fleet,
    simulate,
    simulate_many,
    stack_sims,
    trending_topics,
    trucking_iot,
)

SECONDS = 40.0
DT = 0.5


@pytest.fixture(scope="module")
def fleet_sims():
    sims = compile_fleet(seed_fleet(seed=0))
    assert len(sims) >= 16
    return sims


class TestPadding:
    def test_pad_is_neutral(self, fleet_sims):
        # a padded sim's trajectory equals the unpadded one on real entries
        shape = FleetShape.cover(fleet_sims)
        for sim in (fleet_sims[0], fleet_sims[3]):       # a TT and a TI
            raw = simulate(sim, "tcp", seconds=SECONDS, dt=DT)
            pad = simulate(pad_sim(sim, shape), "tcp", seconds=SECONDS, dt=DT)
            np.testing.assert_allclose(pad.sink_mb, raw.sink_mb, atol=1e-5)
            np.testing.assert_allclose(pad.latency, raw.latency,
                                       rtol=1e-5, atol=1e-4)
            L = raw.link_load.shape[1]
            np.testing.assert_allclose(pad.link_load[:, :L], raw.link_load,
                                       atol=1e-5)
            # padded links carry nothing
            assert np.abs(pad.link_load[:, L:]).max() == 0.0

    def test_stack_shapes(self, fleet_sims):
        stacked, shape = stack_sims(fleet_sims)
        B = len(fleet_sims)
        assert stacked.R.shape == (B, shape.n_flows, shape.n_links)
        assert stacked.M_in.shape == (B, shape.n_insts, shape.n_flows)
        assert stacked.paths.shape == (B, shape.n_paths, shape.n_flows)
        assert stacked.n_apps == shape.n_apps

    def test_pad_rejects_shrinking_apps(self, fleet_sims):
        shape = FleetShape.cover(fleet_sims)
        small = FleetShape(shape.n_flows, shape.n_links, shape.n_insts,
                           shape.n_paths, 0)
        with pytest.raises(ValueError, match="n_apps"):
            pad_sim(fleet_sims[0], small)


class TestBatchParity:
    @pytest.mark.parametrize("policy", ["tcp", "appaware"])
    def test_matches_sequential(self, fleet_sims, policy):
        batch = simulate_many(fleet_sims, policy, seconds=SECONDS, dt=DT)
        for b, sim in enumerate(fleet_sims):
            ref = simulate(sim, policy, seconds=SECONDS, dt=DT)
            rb = batch[b]
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)
            np.testing.assert_allclose(rb.latency, ref.latency,
                                       rtol=1e-4, atol=1e-3)
            np.testing.assert_allclose(rb.link_load, ref.link_load, atol=1e-4)
            # headline metrics within the acceptance tolerance
            assert rb.throughput_tps == pytest.approx(
                ref.throughput_tps, rel=1e-5, abs=1e-4)
            assert rb.avg_latency_s == pytest.approx(
                ref.avg_latency_s, rel=1e-5, abs=1e-4)

    def test_fixed_policy_batched(self):
        # per-scenario fixed rate vectors ride the batch's x_fixed axis
        g = parallelize(trending_topics(), seed=0)
        sims, xs = [], []
        for cap in (1.25, 1.875):
            sim = compile_sim(g, big_switch(8, cap), round_robin(g, 8))
            sims.append(sim)
            xs.append(np.full(g.n_flows, cap / 2, np.float32))
        batch = simulate_many(sims, "fixed", seconds=SECONDS, dt=DT,
                              x_fixed=xs)
        for sim, x, rb in zip(sims, xs, batch):
            ref = simulate(sim, "fixed", seconds=SECONDS, dt=DT, x_fixed=x)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)

    def test_x_fixed_length_mismatch_rejected(self, fleet_sims):
        with pytest.raises(ValueError, match="x_fixed"):
            simulate_many(fleet_sims[:2], "fixed", seconds=5.0,
                          x_fixed=[np.ones(4, np.float32)])


class TestEndToEndRegression:
    """Deterministic seed-workload regression (fixed seeds, fixed grid)."""

    @pytest.mark.parametrize("mk", [trending_topics, trucking_iot])
    def test_appaware_beats_tcp_batched(self, mk):
        g = parallelize(mk(), seed=0)
        sim = compile_sim(g, big_switch(8, 1.25), round_robin(g, 8))
        tcp, aa = (simulate_many([sim], pol, seconds=300.0, dt=DT)[0]
                   for pol in ("tcp", "appaware"))
        assert aa.throughput_tps > tcp.throughput_tps * 1.10
