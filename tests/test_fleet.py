"""Batched multi-scenario engine: padding neutrality, batch-vs-sequential
parity over the ≥16-scenario seed fleet, and the deterministic end-to-end
regression on the paper's seed workloads (TT / junction-heavy TI)."""
import numpy as np
import pytest

from repro.net import big_switch
from repro.streams import (
    FleetRunner,
    FleetShape,
    compile_fleet,
    compile_sim,
    pad_sim,
    parallelize,
    round_robin,
    seed_fleet,
    simulate,
    simulate_many,
    stack_sims,
    trending_topics,
    trucking_iot,
)
from repro.streams.fleet import _plan_buckets, _sim_shape

SECONDS = 40.0
DT = 0.5


@pytest.fixture(scope="module")
def fleet_sims():
    sims = compile_fleet(seed_fleet(seed=0))
    assert len(sims) >= 16
    return sims


class TestPadding:
    def test_pad_is_neutral(self, fleet_sims):
        # a padded sim's trajectory equals the unpadded one on real entries
        shape = FleetShape.cover(fleet_sims)
        for sim in (fleet_sims[0], fleet_sims[3]):       # a TT and a TI
            raw = simulate(sim, "tcp", seconds=SECONDS, dt=DT)
            pad = simulate(pad_sim(sim, shape), "tcp", seconds=SECONDS, dt=DT)
            np.testing.assert_allclose(pad.sink_mb, raw.sink_mb, atol=1e-5)
            np.testing.assert_allclose(pad.latency, raw.latency,
                                       rtol=1e-5, atol=1e-4)
            L = raw.link_load.shape[1]
            np.testing.assert_allclose(pad.link_load[:, :L], raw.link_load,
                                       atol=1e-5)
            # padded links carry nothing
            assert np.abs(pad.link_load[:, L:]).max() == 0.0

    def test_stack_shapes(self, fleet_sims):
        stacked, shape = stack_sims(fleet_sims)
        B = len(fleet_sims)
        assert stacked.R.shape == (B, shape.n_flows, shape.n_links)
        assert stacked.M_in.shape == (B, shape.n_insts, shape.n_flows)
        assert stacked.path_w.shape == (B, shape.n_flows)
        assert stacked.n_apps == shape.n_apps

    def test_pad_rejects_shrinking_apps(self, fleet_sims):
        shape = FleetShape.cover(fleet_sims)
        small = FleetShape(shape.n_flows, shape.n_links, shape.n_insts, 0)
        with pytest.raises(ValueError, match="n_apps"):
            pad_sim(fleet_sims[0], small)


class TestBatchParity:
    @pytest.mark.parametrize("policy", ["tcp", "appaware"])
    def test_matches_sequential(self, fleet_sims, policy):
        batch = simulate_many(fleet_sims, policy, seconds=SECONDS, dt=DT)
        for b, sim in enumerate(fleet_sims):
            ref = simulate(sim, policy, seconds=SECONDS, dt=DT)
            rb = batch[b]
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)
            np.testing.assert_allclose(rb.latency, ref.latency,
                                       rtol=1e-4, atol=1e-3)
            np.testing.assert_allclose(rb.link_load, ref.link_load, atol=1e-4)
            # headline metrics within the acceptance tolerance
            assert rb.throughput_tps == pytest.approx(
                ref.throughput_tps, rel=1e-5, abs=1e-4)
            assert rb.avg_latency_s == pytest.approx(
                ref.avg_latency_s, rel=1e-5, abs=1e-4)

    def test_fixed_policy_batched(self):
        # per-scenario fixed rate vectors ride the batch's x_fixed axis
        g = parallelize(trending_topics(), seed=0)
        sims, xs = [], []
        for cap in (1.25, 1.875):
            sim = compile_sim(g, big_switch(8, cap), round_robin(g, 8))
            sims.append(sim)
            xs.append(np.full(g.n_flows, cap / 2, np.float32))
        batch = simulate_many(sims, "fixed", seconds=SECONDS, dt=DT,
                              x_fixed=xs)
        for sim, x, rb in zip(sims, xs, batch):
            ref = simulate(sim, "fixed", seconds=SECONDS, dt=DT, x_fixed=x)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)

    def test_x_fixed_length_mismatch_rejected(self, fleet_sims):
        with pytest.raises(ValueError, match="x_fixed"):
            simulate_many(fleet_sims[:2], "fixed", seconds=5.0,
                          x_fixed=[np.ones(4, np.float32)])


class TestFleetRunner:
    def test_bucket_plan_covers_each_sim_once(self, fleet_sims):
        for k in (1, 2, 4, 8):
            plan = _plan_buckets(fleet_sims, k, exact_apps=False)
            assert len(plan) <= max(k, 1)
            seen = sorted(i for idxs, _ in plan for i in idxs)
            assert seen == list(range(len(fleet_sims)))
            for idxs, shape in plan:
                for i in idxs:  # bucket shape covers every member
                    s = _sim_shape(fleet_sims[i])
                    assert all(a <= b for a, b in zip(
                        (s.n_flows, s.n_links, s.n_insts, s.n_apps),
                        (shape.n_flows, shape.n_links, shape.n_insts,
                         shape.n_apps)))

    def test_no_recompile_on_repeat_calls(self, fleet_sims):
        runner = FleetRunner()
        runner.run(fleet_sims, "tcp", seconds=5.0, dt=DT)
        size = runner.compile_cache_size()
        assert size > 0
        out2 = runner.run(fleet_sims, "tcp", seconds=5.0, dt=DT)
        out3 = runner.run(list(fleet_sims), "tcp", seconds=5.0, dt=DT)
        assert runner.compile_cache_size() == size  # jit cache-miss counter
        for a, b in zip(out2, out3):
            np.testing.assert_array_equal(a.sink_mb, b.sink_mb)

    def test_runner_matches_sequential(self, fleet_sims):
        runner = FleetRunner(max_buckets=3)
        batch = runner.run(fleet_sims[:6], "tcp", seconds=20.0, dt=DT)
        for sim, rb in zip(fleet_sims[:6], batch):
            ref = simulate(sim, "tcp", seconds=20.0, dt=DT)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)


def _two_app_sim(n_apps: int, cap: float, seed: int = 0):
    g = parallelize(trending_topics(), seed=seed)
    app_of_inst = (np.arange(g.n_instances) % n_apps).astype(np.int32)
    return compile_sim(g, big_switch(8, cap), round_robin(g, 8),
                       app_of_inst=app_of_inst, n_apps=n_apps)


class TestMixedScheduledStatic:
    """Fleets mixing in-run capacity schedules and static scenarios batch
    together (padded schedules are exact no-ops) without recompiling."""

    def _mixed_fleet(self):
        from repro.net import big_switch, link_failure_schedule

        g = parallelize(trending_topics(), seed=0)
        topo = big_switch(8, 1.25)
        static = compile_sim(g, topo, round_robin(g, 8))
        sched = link_failure_schedule(topo, [0, 1], 10.0, 20.0, degrade=0.1)
        dyn = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        return [static, dyn, static, dyn]

    def test_no_recompile_and_parity(self):
        sims = self._mixed_fleet()
        runner = FleetRunner()
        batch = runner.run(sims, "tcp", seconds=30.0, dt=DT)
        size = runner.compile_cache_size()
        batch2 = runner.run(sims, "tcp", seconds=30.0, dt=DT)
        assert runner.compile_cache_size() == size
        for sim, rb, rb2 in zip(sims, batch, batch2):
            ref = simulate(sim, "tcp", seconds=30.0, dt=DT)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)
            np.testing.assert_array_equal(rb.sink_mb, rb2.sink_mb)
        # scheduled members report their capacity trajectory, static don't
        assert batch[0].caps_t is None and batch[1].caps_t is not None
        np.testing.assert_allclose(
            batch[1].caps_t,
            simulate(sims[1], "tcp", seconds=30.0, dt=DT).caps_t,
            atol=1e-6)

    def test_mixed_fleet_merges_into_one_bucket(self):
        # schedule axes pad like any other dim: forcing one bucket covers
        # the static members with neutral (never-active) events
        sims = self._mixed_fleet()
        plan = _plan_buckets(sims, 1, exact_apps=False)
        assert len(plan) == 1
        assert plan[0][1].n_events == max(s.ev_t0.shape[0] for s in sims)


class TestAppfairMixedApps:
    def test_heterogeneous_n_apps_batch_parity(self):
        # pre-PR this raised ValueError; the runner now buckets appfair
        # fleets by exact app count, so mixed-n_apps fleets batch exactly
        sims = [_two_app_sim(2, 1.25), _two_app_sim(3, 1.875),
                _two_app_sim(2, 2.5)]
        batch = simulate_many(sims, "appfair", seconds=SECONDS, dt=DT)
        for sim, rb in zip(sims, batch):
            ref = simulate(sim, "appfair", seconds=SECONDS, dt=DT)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)
            np.testing.assert_allclose(rb.sink_mb_app, ref.sink_mb_app,
                                       atol=1e-4)


class TestConservationThroughFusedSolver:
    """Per-tick conservation (bytes in = bytes out + queued) with the rate
    vector coming from the NEW fused fixed-trip max-min solver — exactly
    the tcp policy's per-tick path, demand clamp and all — plus the fleet
    assertion that a scheduled mix of static and in-run-failure scenarios
    still shares buckets/executables (no recompile) through that solver."""

    def _cons_setup(self, schedule=None):
        from repro.net import big_switch
        from repro.streams import Edge, Grouping, Operator, StreamApp

        app = StreamApp(
            "cons",
            [Operator("src", 1, gen_rate=0.8, proc_rate=100.0),
             Operator("mid", 2, proc_rate=100.0, selectivity=1.0),
             Operator("sink", 1, proc_rate=100.0, selectivity=0.0)],
            [Edge("src", "mid", Grouping.SHUFFLE),
             Edge("mid", "sink", Grouping.GLOBAL)],
        )
        g = parallelize(app, seed=0)
        topo = big_switch(4, 5.0)
        return g, topo, compile_sim(g, topo, round_robin(g, 4),
                                    schedule=schedule)

    def test_per_tick_conservation_with_fused_rates(self):
        import jax.numpy as jnp

        from repro.core.tcp import maxmin_order_init
        from repro.net import link_failure_schedule
        from repro.streams.simulator import _tcp_rates, _tick

        sched = link_failure_schedule(big_switch(4, 5.0), [0, 1],
                                      10.0, 20.0, degrade=0.0)
        g, topo, sim = self._cons_setup(schedule=sched)
        F = g.n_flows
        qcap = 8.0
        Qs = Qr = jnp.zeros((F,), jnp.float32)
        prod_rate = drain_ewma = jnp.zeros((F,), jnp.float32)
        delivered = 0.0
        base = np.asarray(sim.caps)
        oc = maxmin_order_init(sim.R.shape[0])
        for t in range(60):  # 30 s: failure at 10 s, recovery at 20 s
            caps_t = jnp.asarray(sched.caps_at(base, t * DT), jnp.float32)
            # the real tcp policy step: demand-clamped fused max-min with
            # the demand-order carry threaded tick to tick (static routing:
            # the active R is just sim.R)
            x, oc, _ = _tcp_rates(sim, sim.R, caps_t, Qs, Qr, prod_rate,
                                  drain_ewma, DT, qcap, oc)
            Qs, Qr, transfer, drain, (sink, _, _, load) = _tick(
                sim, Qs, Qr, x, DT, qcap, caps_t=caps_t)
            t_in = sim.M_in @ transfer
            out_i = sim.selectivity * t_in + sim.gen_rate * DT
            prod_rate = out_i[sim.src_of_flow] * sim.w_of_flow / DT
            drain_ewma = 0.5 * drain_ewma + 0.5 * drain
            delivered += float(sink)
            # fused rates never oversubscribe the *scheduled* capacity
            assert np.all(np.asarray(load) <= np.asarray(caps_t) * (1 + 1e-3)
                          + 1e-6)
            # nothing minted, nothing lost — at every tick
            generated = 0.8 * DT * (t + 1)
            total = delivered + float(jnp.sum(Qs) + jnp.sum(Qr))
            np.testing.assert_allclose(total, generated, rtol=1e-3)
        assert delivered > 0.0

    def test_mixed_fleet_shares_buckets_and_conserves(self):
        from repro.net import link_failure_schedule

        g, topo, static = self._cons_setup()
        sched = link_failure_schedule(topo, [0, 1], 10.0, 20.0, degrade=0.0)
        _, _, dyn = self._cons_setup(schedule=sched)
        sims = [static, dyn, static, dyn]
        # mixed static + in-run-failure fleet: one bucket (padded schedules
        # are exact no-ops), and repeat calls recompile nothing
        plan = _plan_buckets(sims, 1, exact_apps=False)
        assert len(plan) == 1
        runner = FleetRunner()
        batch = runner.run(sims, "tcp", seconds=30.0, dt=DT)
        size = runner.compile_cache_size()
        batch2 = runner.run(sims, "tcp", seconds=30.0, dt=DT)
        assert runner.compile_cache_size() == size
        gen_per_s = 0.8
        for sim, rb, rb2 in zip(sims, batch, batch2):
            np.testing.assert_array_equal(rb.sink_mb, rb2.sink_mb)
            ref = simulate(sim, "tcp", seconds=30.0, dt=DT)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)
            # outside-view conservation: cumulative delivery through the
            # batched solver path never exceeds cumulative generation
            ticks = np.arange(1, rb.sink_mb.shape[0] + 1)
            cum = np.cumsum(rb.sink_mb)
            assert np.all(cum <= gen_per_s * DT * ticks * (1 + 1e-3) + 1e-4)


class TestEndToEndRegression:
    """Deterministic seed-workload regression (fixed seeds, fixed grid)."""

    @pytest.mark.parametrize("mk", [trending_topics, trucking_iot])
    def test_appaware_beats_tcp_batched(self, mk):
        g = parallelize(mk(), seed=0)
        sim = compile_sim(g, big_switch(8, 1.25), round_robin(g, 8))
        tcp, aa = (simulate_many([sim], pol, seconds=300.0, dt=DT)[0]
                   for pol in ("tcp", "appaware"))
        assert aa.throughput_tps > tcp.throughput_tps * 1.10
