"""Unit + property tests for the paper's core: eq.(3)/(4) solvers, Alg. 1,
TCP max-min baseline, §VII multi-app fairness."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    FlowState,
    OnlineAllocator,
    jain_index,
    maxmin_rates,
    solve_downlink,
    solve_uplink,
    group_by_throughput,
    ewma_throughput,
)
from repro.core.allocator import (
    LinkProgram,
    _per_link_rates,
    _per_link_rates_vmap,
)
from repro.net import big_switch, fat_tree, LinkKind


# ---------------------------------------------------------------- eq. (3)
class TestUplink:
    def test_proportional(self):
        w = jnp.array([1.0, 3.0, 6.0])
        x = solve_uplink(w, jnp.ones(3), 100.0)
        np.testing.assert_allclose(np.asarray(x), [10.0, 30.0, 60.0], rtol=1e-6)

    def test_mask_respected(self):
        w = jnp.array([1.0, 1.0, 1.0])
        x = solve_uplink(w, jnp.array([1.0, 0.0, 1.0]), 10.0)
        assert x[1] == 0.0
        np.testing.assert_allclose(float(x.sum()), 10.0, rtol=1e-6)

    def test_zero_demand_falls_back_to_equal_split(self):
        x = solve_uplink(jnp.zeros(4), jnp.ones(4), 8.0)
        np.testing.assert_allclose(np.asarray(x), [2.0] * 4, rtol=1e-6)

    @settings(max_examples=50, deadline=None)
    @given(
        w=st.lists(st.floats(0.0, 1e4), min_size=2, max_size=32),
        cap=st.floats(1e-2, 1e4),
    )
    def test_property_capacity_and_minmax(self, w, cap):
        w = jnp.asarray(w, jnp.float32)
        x = solve_uplink(w, jnp.ones_like(w), cap)
        assert float(x.min()) >= 0.0
        np.testing.assert_allclose(float(x.sum()), cap, rtol=1e-4)
        # min-max optimality: transfer times w/x equal across positive-weight
        # flows (excluding denormals that drown in fp32 rounding)
        wn = np.asarray(w)
        pos = wn > max(1e-6 * wn.max(), 1e-20)
        if pos.sum() >= 2:
            t = wn[pos] / np.maximum(np.asarray(x)[pos], 1e-12)
            np.testing.assert_allclose(t, t[0], rtol=1e-3)


# ---------------------------------------------------------------- eq. (4)
class TestDownlink:
    def test_equal_drain_times(self):
        L = jnp.array([10.0, 1.0, 0.5])
        rho = jnp.array([2.0, 3.0, 1.0])
        x = solve_downlink(L, rho, jnp.ones(3), 5.0, 1.0)
        np.testing.assert_allclose(float(x.sum()), 5.0, rtol=1e-5)
        drain = (np.asarray(L) + np.asarray(x)) / np.asarray(rho)
        pos = np.asarray(x) > 1e-9
        # active flows share one drain time θ; clipped flows exceed it (KKT)
        theta = drain[pos][0]
        np.testing.assert_allclose(drain[pos], theta, rtol=1e-4)
        assert np.all(drain[~pos] >= theta - 1e-4)

    def test_starved_join_gets_more(self):
        # paper: lower receiver backlog (starved join input) => MORE bandwidth
        L = jnp.array([8.0, 0.1])
        rho = jnp.array([1.0, 1.0])
        x = solve_downlink(L, rho, jnp.ones(2), 4.0, 1.0)
        assert float(x[1]) > float(x[0])

    @settings(max_examples=50, deadline=None)
    @given(
        n=st.integers(2, 24),
        cap=st.floats(0.1, 1e3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_waterfill_kkt(self, n, cap, seed):
        rng = np.random.default_rng(seed)
        L = jnp.asarray(rng.uniform(0, 50, n), jnp.float32)
        rho = jnp.asarray(rng.uniform(0.1, 20, n), jnp.float32)
        x = solve_downlink(L, rho, jnp.ones(n), cap, 1.0)
        xn = np.asarray(x)
        assert xn.min() >= 0.0
        np.testing.assert_allclose(xn.sum(), cap, rtol=1e-3)
        drain = (np.asarray(L) + xn) / np.asarray(rho)
        pos = xn > cap * 1e-5
        if pos.sum() >= 1:
            theta = np.median(drain[pos])
            np.testing.assert_allclose(drain[pos], theta, rtol=5e-3)
            if (~pos).sum():
                assert np.all(drain[~pos] >= theta * (1 - 5e-3))


# ---------------------------------------------------- fused per-link solve
def _rand_program(rng, F, L, p=0.4, zero_cap_frac=0.0):
    R = (rng.random((F, L)) < p).astype(np.float32)
    caps = rng.uniform(0.0, 50.0, L)
    if zero_cap_frac:
        caps[rng.random(L) < zero_cap_frac] = 0.0
    return LinkProgram(
        R=jnp.asarray(R),
        capacity=jnp.asarray(caps, jnp.float32),
        kind=jnp.asarray(rng.integers(0, 3, L), jnp.int32),
    )


def _rand_flowstate(rng, n):
    return FlowState(
        *[jnp.asarray(rng.uniform(0, 10, n), jnp.float32) for _ in range(5)])


class TestFusedPerLinkRates:
    """The fused single-argsort batched solve must equal the per-link vmap
    reference (`_per_link_rates_vmap`) to 1e-5 on every link row."""

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_parity_random(self, seed):
        rng = np.random.default_rng(seed)
        F, L = int(rng.integers(1, 48)), int(rng.integers(1, 32))
        prog = _rand_program(rng, F, L, p=float(rng.uniform(0.1, 0.9)))
        state = _rand_flowstate(rng, F)
        dt = float(rng.choice([0.5, 1.0, 5.0]))
        a = np.asarray(_per_link_rates(prog, state, dt))
        b = np.asarray(_per_link_rates_vmap(prog, state, dt))
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_all_internal_links(self):
        # INTERNAL-only programs take the uplink closed form on every row
        rng = np.random.default_rng(0)
        F, L = 9, 5
        prog = _rand_program(rng, F, L)
        prog = LinkProgram(prog.R, prog.capacity,
                           jnp.full((L,), int(LinkKind.INTERNAL), jnp.int32))
        state = _rand_flowstate(rng, F)
        np.testing.assert_allclose(
            np.asarray(_per_link_rates(prog, state, 1.0)),
            np.asarray(_per_link_rates_vmap(prog, state, 1.0)), atol=1e-5)

    def test_zero_demand(self):
        rng = np.random.default_rng(1)
        F, L = 7, 6
        prog = _rand_program(rng, F, L)
        z = jnp.zeros((F,), jnp.float32)
        state = FlowState(z, z, z, z, z)
        a = np.asarray(_per_link_rates(prog, state, 0.5))
        b = np.asarray(_per_link_rates_vmap(prog, state, 0.5))
        np.testing.assert_allclose(a, b, atol=1e-5)
        # equal-split fallback still fills every masked uplink exactly
        up = np.asarray(prog.kind) != int(LinkKind.DOWNLINK)
        mask = np.asarray(prog.R).T > 0
        has = mask.any(1) & up
        np.testing.assert_allclose(
            a.sum(1)[has], np.asarray(prog.capacity)[has], rtol=1e-5)

    def test_single_flow(self):
        rng = np.random.default_rng(2)
        prog = _rand_program(rng, 1, 4, p=1.0)
        state = _rand_flowstate(rng, 1)
        np.testing.assert_allclose(
            np.asarray(_per_link_rates(prog, state, 1.0)),
            np.asarray(_per_link_rates_vmap(prog, state, 1.0)), atol=1e-5)

    def test_zero_capacity_links(self):
        rng = np.random.default_rng(3)
        prog = _rand_program(rng, 12, 8, zero_cap_frac=0.5)
        state = _rand_flowstate(rng, 12)
        a = np.asarray(_per_link_rates(prog, state, 1.0))
        b = np.asarray(_per_link_rates_vmap(prog, state, 1.0))
        np.testing.assert_allclose(a, b, atol=1e-5)
        dead = np.asarray(prog.capacity) == 0.0
        assert np.abs(a[dead]).max() == 0.0

    def test_backfill_matches_naive_form(self):
        # lean backfill == the naive [F, L] share/gain formulation
        from repro.core.allocator import backfill, _EPS

        rng = np.random.default_rng(5)
        F, L = 10, 6
        prog = _rand_program(rng, F, L, p=0.5)
        x0 = rng.uniform(0, 3, F).astype(np.float32)

        R, cap = np.asarray(prog.R), np.asarray(prog.capacity)
        on_net = R.sum(1) > 0
        x = x0.copy()
        for _ in range(8):
            load = x @ R
            resid = np.maximum(cap - load, 0.0)
            share = x[:, None] / np.maximum(load, _EPS)[None, :]
            gain = np.where(R > 0, share * resid[None, :], np.inf)
            inc = gain.min(axis=1)
            inc = np.where(on_net & np.isfinite(inc), inc, 0.0)
            x = x + 0.9 * inc
        np.testing.assert_allclose(
            np.asarray(backfill(jnp.asarray(x0), prog, iters=8)), x,
            rtol=1e-5, atol=1e-5)

    def test_allocate_end_to_end_unchanged(self):
        # the fused pipeline (single masked kind-min + lean backfill) must
        # reproduce the reference composition built from the vmap solver
        from repro.core.allocator import allocate, backfill, _EPS, _INF

        rng = np.random.default_rng(4)
        F, L = 15, 10
        prog = _rand_program(rng, F, L)
        state = _rand_flowstate(rng, F)

        per_link = _per_link_rates_vmap(prog, state, 1.0)
        kind = prog.kind

        def min_over(mask_kind):  # the pre-fusion two-pass reduction
            sel = (kind == mask_kind)[:, None] & (prog.R.T > 0)
            return jnp.min(jnp.where(sel, per_link, _INF), axis=0)

        x = jnp.minimum(min_over(int(LinkKind.UPLINK)),
                        min_over(int(LinkKind.DOWNLINK)))
        x = jnp.where(jnp.isfinite(x), x, 0.0)
        load = x @ prog.R
        is_int = kind == int(LinkKind.INTERNAL)
        scale_l = jnp.where(is_int & (load > prog.capacity),
                            prog.capacity / jnp.maximum(load, _EPS), 1.0)
        x = x * jnp.where((prog.R > 0) & is_int[None, :],
                          scale_l[None, :], 1.0).min(axis=1)
        ref = backfill(x, prog, iters=8)
        np.testing.assert_allclose(
            np.asarray(allocate(prog, state, dt=1.0)), np.asarray(ref),
            atol=1e-4)


# ----------------------------------------------------- chunked-links solve
class TestChunkedPerLinkRates:
    """``allocate(..., block_links=k)`` processes the link axis in chunks
    (bounded [block, F] intermediates) and must reproduce the fused solve
    exactly — including block sizes that don't divide L, exceed L, or
    degenerate to one link per chunk."""

    @pytest.mark.parametrize("blk", [1, 7, 16, 64])
    def test_parity_vs_fused(self, blk):
        from repro.core.allocator import _per_link_rates_chunked

        rng = np.random.default_rng(11)
        F, L = 40, 37
        prog = _rand_program(rng, F, L, p=0.3)
        state = _rand_flowstate(rng, F)
        a = np.asarray(_per_link_rates(prog, state, 5.0))
        b = np.asarray(_per_link_rates_chunked(prog, state, 5.0, blk))
        np.testing.assert_allclose(a, b, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_allocate_parity(self, seed):
        from repro.core.allocator import allocate

        rng = np.random.default_rng(seed)
        F, L = int(rng.integers(2, 40)), int(rng.integers(1, 30))
        blk = int(rng.integers(1, L + 8))
        prog = _rand_program(rng, F, L, p=float(rng.uniform(0.1, 0.8)))
        state = _rand_flowstate(rng, F)
        xa = np.asarray(allocate(prog, state, dt=1.0))
        xb = np.asarray(allocate(prog, state, dt=1.0, block_links=blk))
        np.testing.assert_allclose(xa, xb, atol=1e-5)

    def test_zero_demand_chunked(self):
        from repro.core.allocator import _per_link_rates_chunked

        rng = np.random.default_rng(12)
        F, L = 9, 10
        prog = _rand_program(rng, F, L)
        z = jnp.zeros((F,), jnp.float32)
        state = FlowState(z, z, z, z, z)
        np.testing.assert_allclose(
            np.asarray(_per_link_rates_chunked(prog, state, 0.5, 4)),
            np.asarray(_per_link_rates(prog, state, 0.5)), atol=1e-5)


# ------------------------------------------------------------- Algorithm 1
def _mk_state(rng, n):
    ls_t = rng.uniform(0, 5, n)
    lr_t = rng.uniform(0, 5, n)
    v = rng.uniform(0.1, 20, n)
    ls_t1 = rng.uniform(0, 10, n)
    lr_t1 = rng.uniform(0, np.minimum(v + lr_t, 10))
    return FlowState(*[jnp.asarray(a, jnp.float32) for a in (ls_t, lr_t, v, ls_t1, lr_t1)])


class TestAlgorithm1:
    @pytest.mark.parametrize("topo_fn", [lambda: big_switch(4, 100.0), fat_tree])
    def test_feasibility(self, topo_fn):
        topo = topo_fn()
        rng = np.random.default_rng(0)
        m = topo.n_machines
        flows = [(int(a), int(b)) for a, b in rng.integers(0, m, (12, 2))]
        alloc = OnlineAllocator.from_topology(topo, flows)
        x = np.asarray(alloc(_mk_state(rng, len(flows))))
        assert x.min() >= -1e-5
        load = x @ topo.routing_matrix(flows)
        assert np.all(load <= topo.capacities * (1 + 1e-4))

    def test_internal_link_scale_down(self):
        # throttle internal links so the fat-tree core becomes the bottleneck
        topo = fat_tree(up=125.0).set_capacity(LinkKind.INTERNAL, 10.0)
        flows = [(0, 2), (0, 4), (1, 6)]  # cross-rack => traverse internals
        rng = np.random.default_rng(1)
        alloc = OnlineAllocator.from_topology(topo, flows)
        x = np.asarray(alloc(_mk_state(rng, 3)))
        load = x @ topo.routing_matrix(flows)
        kinds = topo.link_kinds
        assert np.all(load[kinds == int(LinkKind.INTERNAL)] <= 10.0 + 1e-3)

    @pytest.mark.parametrize("topo_fn", [lambda: big_switch(4, 100.0), fat_tree])
    def test_pallas_solver_parity(self, topo_fn):
        # allocate(solver="pallas") — the bisection waterfill kernel in
        # interpret mode — must match the exact sort-based solve end-to-end
        # (through kind-min, internal scale-down, and backfill)
        topo = topo_fn()
        rng = np.random.default_rng(3)
        m = topo.n_machines
        flows = [(int(a), int(b)) for a, b in rng.integers(0, m, (14, 2))]
        a_sort = OnlineAllocator.from_topology(topo, flows, solver="sort")
        a_pal = OnlineAllocator.from_topology(topo, flows, solver="pallas")
        for _ in range(3):
            st_ = _mk_state(rng, len(flows))
            xs = np.asarray(a_sort(st_))
            xp = np.asarray(a_pal(st_))
            np.testing.assert_allclose(xs, xp, rtol=2e-3, atol=2e-3)
            # and the pallas path alone stays feasible
            load = xp @ topo.routing_matrix(flows)
            assert np.all(load <= topo.capacities * (1 + 1e-3))

    def test_unknown_solver_rejected(self):
        topo = big_switch(2, 10.0)
        alloc = OnlineAllocator.from_topology(topo, [(0, 1)], solver="nope")
        with pytest.raises(ValueError, match="solver"):
            alloc(_mk_state(np.random.default_rng(0), 1))

    def test_backfill_utilization(self):
        # single bottleneck uplink shared by 3 flows: backfill should leave
        # the link ~fully utilized (paper reports 97-99%)
        topo = big_switch(4, 50.0)
        flows = [(0, 1), (0, 2), (0, 3)]
        rng = np.random.default_rng(2)
        alloc = OnlineAllocator.from_topology(topo, flows)
        x = np.asarray(alloc(_mk_state(rng, 3)))
        up_load = x.sum()
        assert up_load >= 0.95 * 50.0


# ------------------------------------------------------------ TCP baseline
class TestMaxMin:
    def test_textbook_example(self):
        # one shared link C=10 with 2 flows; one private link C=100 w/ 1 flow
        R = jnp.asarray(np.array([[1, 0], [1, 1]], np.float32))
        cap = jnp.array([10.0, 100.0])
        x = np.asarray(maxmin_rates(R, cap))
        np.testing.assert_allclose(x, [5.0, 5.0], rtol=1e-5)

    def test_progressive_filling(self):
        topo = fat_tree()
        flows = [(0, 2), (0, 3), (1, 2)]
        R = jnp.asarray(topo.routing_matrix(flows))
        x = np.asarray(maxmin_rates(R, jnp.asarray(topo.capacities)))
        # up0 shared by f0,f1; down2 shared by f0,f2 => everyone 62.5 except
        # after freezing, remaining capacity goes to the less-contended flow
        load = x @ np.asarray(topo.routing_matrix(flows))
        assert np.all(load <= topo.capacities + 1e-3)
        # max-min characterization: every flow has a saturated bottleneck link
        # where it has the max rate among traversing flows
        Rn = topo.routing_matrix(flows)
        for f in range(len(flows)):
            links = np.nonzero(Rn[f])[0]
            ok = False
            for l in links:
                on_l = x[Rn[:, l] > 0]
                if load[l] >= topo.capacities[l] - 1e-3 and x[f] >= on_l.max() - 1e-3:
                    ok = True
            assert ok, f"flow {f} has no max-min bottleneck"

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), nf=st.integers(1, 20))
    def test_property_feasible_and_bottlenecked(self, seed, nf):
        rng = np.random.default_rng(seed)
        topo = fat_tree()
        flows = [tuple(rng.choice(topo.n_machines, 2, replace=False)) for _ in range(nf)]
        R = topo.routing_matrix(flows)
        x = np.asarray(maxmin_rates(jnp.asarray(R, jnp.float32), jnp.asarray(topo.capacities, jnp.float32)))
        x = np.where(np.isfinite(x), x, 0.0)
        load = x @ R
        assert np.all(load <= topo.capacities * (1 + 1e-3))
        for f in range(nf):
            links = np.nonzero(R[f])[0]
            if len(links) == 0:
                continue
            assert any(
                load[l] >= topo.capacities[l] * (1 - 1e-3)
                and x[f] >= x[R[:, l] > 0].max() - 1e-3
                for l in links
            )


# --------------------------------------------------------------- §VII fair
class TestMultiApp:
    def test_jain(self):
        assert float(jain_index(jnp.ones(8))) == pytest.approx(1.0)
        assert float(jain_index(jnp.array([1.0, 0, 0, 0]))) == pytest.approx(0.25)

    def test_ewma(self):
        assert float(ewma_throughput(10.0, 2.0, 0.75)) == pytest.approx(8.0)

    def test_grouping_lowest_gets_priority_zero(self):
        mu = jnp.array([5.0, 1.0, 9.0, 3.0])
        prio = np.asarray(group_by_throughput(mu, 4))
        assert prio[1] == 0 and prio[2] == 3

    def test_app_fairness_beats_tcp(self):
        """Fig. 13 scenario: 5 apps with 1..5 flows across one bottleneck.

        App-Fair's fairness is a *time-averaged* property: strict priority
        serves the lowest-throughput group each interval and the EWMA +
        displacement rotates groups, so cumulative throughput equalizes
        (paper: Jain 0.98 vs TCP 0.84).
        """
        from repro.core import AppFairScheduler

        n_apps = 5
        app_of_flow = np.concatenate([[a] * (a + 1) for a in range(n_apps)])
        F = len(app_of_flow)
        R = jnp.ones((F, 1), jnp.float32)
        cap = jnp.array([100.0])
        # TCP: static flow-level max-min => app share ∝ #flows
        x_tcp = np.asarray(maxmin_rates(R, cap))
        tcp_app = np.array([x_tcp[app_of_flow == a].sum() for a in range(n_apps)])
        j_tcp = float(jain_index(jnp.asarray(tcp_app)))

        sched = AppFairScheduler(n_apps, alpha=0.5, n_groups=5)
        state = sched.init()
        aof = jnp.asarray(app_of_flow)
        total = np.zeros(n_apps)
        prev = np.zeros(n_apps, np.float32)
        T = 60
        for _ in range(T):
            state, x = sched.step(state, jnp.asarray(prev), R, cap, aof)
            xn = np.asarray(x)
            per_app = np.array([xn[app_of_flow == a].sum() for a in range(n_apps)])
            total += per_app
            prev = per_app.astype(np.float32)
        j_fair = float(jain_index(jnp.asarray(total / T)))
        assert j_fair > j_tcp
        assert j_fair > 0.9
        assert np.all(total > 0)  # no starvation
