"""Property-testing shim: real `hypothesis` when installed, else a minimal
deterministic fallback so the tier-1 suite collects and runs everywhere.

The fallback implements exactly the subset this repo's tests use —
``@settings(max_examples=…, deadline=…)`` stacked on ``@given(**kwargs)``
with ``st.integers`` / ``st.floats`` / ``st.lists`` strategies — by drawing
``max_examples`` pseudo-random examples from a seed derived from the test's
qualified name (stable across runs and processes; no shrinking, no
database). Import from here instead of `hypothesis`:

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import inspect
    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: np.random.Generator):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int = 0, max_value: int = 2**31 - 1):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw):
            # bias an occasional endpoint in: hypothesis probes boundaries
            def draw(rng):
                r = rng.random()
                if r < 0.05:
                    return float(min_value)
                if r < 0.10:
                    return float(max_value)
                return float(rng.uniform(min_value, max_value))
            return _Strategy(draw)

        @staticmethod
        def lists(elements: "_Strategy", min_size: int = 0,
                  max_size: int = 10):
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))
            ])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(
                lambda rng: items[int(rng.integers(0, len(items)))])

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
        """Applied *outside* ``given``: annotate its wrapper."""
        def deco(fn):
            fn._max_examples = int(max_examples)
            return fn
        return deco

    def given(**strategy_kw):
        def deco(fn):
            sig = inspect.signature(fn)
            kept = [p for name, p in sig.parameters.items()
                    if name not in strategy_kw]
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())

            def wrapper(*args):
                rng = np.random.default_rng(seed)
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                    fn(*args, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # hide strategy params from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper
        return deco
