"""Per-kernel validation (assignment: sweep shapes/dtypes, assert_allclose
against the pure-jnp ref.py oracle; interpret mode executes the kernel body
on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ops import (
    flash_attention,
    flash_attention_reference,
)
from repro.kernels.ssd_scan.ops import ssd_scan, ssd_reference
from repro.kernels.waterfill.ops import (
    waterfill,
    waterfill_flows,
    waterfill_reference,
)


# ------------------------------------------------------------- waterfill
class TestWaterfill:
    @pytest.mark.parametrize("L,F", [(4, 16), (10, 37), (32, 128), (7, 200)])
    @pytest.mark.parametrize("dt", [0.5, 1.0, 5.0])
    def test_matches_oracle(self, L, F, dt):
        rng = np.random.default_rng(L * F)
        w = rng.uniform(0, 20, (L, F)).astype(np.float32)
        bl = rng.uniform(0, 30, (L, F)).astype(np.float32)
        rho = rng.uniform(0.1, 10, (L, F)).astype(np.float32)
        mask = (rng.random((L, F)) < 0.7).astype(np.float32)
        cap = rng.uniform(1, 50, L).astype(np.float32)
        kind = rng.integers(0, 2, L).astype(np.int32)
        out = np.asarray(waterfill(w, bl, rho, mask, cap, kind, dt=dt))
        ref = np.asarray(waterfill_reference(
            *(jnp.asarray(a) for a in (w, bl, rho, mask, cap, kind)), dt))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_feasible(self, seed):
        rng = np.random.default_rng(seed)
        L, F = int(rng.integers(1, 12)), int(rng.integers(2, 64))
        w = rng.uniform(0, 20, (L, F)).astype(np.float32)
        bl = rng.uniform(0, 30, (L, F)).astype(np.float32)
        rho = rng.uniform(0.1, 10, (L, F)).astype(np.float32)
        mask = (rng.random((L, F)) < 0.8).astype(np.float32)
        cap = rng.uniform(1, 50, L).astype(np.float32)
        kind = rng.integers(0, 2, L).astype(np.int32)
        out = np.asarray(waterfill(w, bl, rho, mask, cap, kind))
        assert out.min() >= -1e-5
        assert np.all(out * (1 - mask) == 0)
        has = mask.sum(1) > 0
        np.testing.assert_allclose(out.sum(1)[has], cap[has], rtol=1e-3)

    def test_all_zero_demand(self):
        # zero backlog everywhere (downlink) / zero weight (uplink): the
        # bisection and the exact sort must agree on the degenerate fills
        L, F = 6, 32
        z = np.zeros((L, F), np.float32)
        rho = np.full((L, F), 2.0, np.float32)
        mask = np.ones((L, F), np.float32)
        cap = np.full(L, 12.0, np.float32)
        kind = np.arange(L, dtype=np.int32) % 2
        out = np.asarray(waterfill(z, z, rho, mask, cap, kind, dt=1.0))
        ref = np.asarray(waterfill_reference(
            *(jnp.asarray(a) for a in (z, z, rho, mask, cap, kind)), 1.0))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
        # work conservation even with no demand signal
        np.testing.assert_allclose(out.sum(1), cap, rtol=1e-3)

    def test_single_flow_takes_link(self):
        # one masked flow per link: it gets the whole capacity on both kinds
        L, F = 4, 16
        rng = np.random.default_rng(7)
        w = rng.uniform(0.1, 5, (L, F)).astype(np.float32)
        bl = rng.uniform(0, 10, (L, F)).astype(np.float32)
        rho = rng.uniform(0.5, 4, (L, F)).astype(np.float32)
        mask = np.zeros((L, F), np.float32)
        keep = rng.integers(0, F, L)
        mask[np.arange(L), keep] = 1.0
        cap = rng.uniform(1, 20, L).astype(np.float32)
        kind = np.array([0, 1, 0, 1], np.int32)
        out = np.asarray(waterfill(w, bl, rho, mask, cap, kind, dt=0.5))
        ref = np.asarray(waterfill_reference(
            *(jnp.asarray(a) for a in (w, bl, rho, mask, cap, kind)), 0.5))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(out[np.arange(L), keep], cap, rtol=1e-3)

    def test_vector_inputs_match_dense(self):
        # waterfill_flows([F] vectors) == waterfill on the dense broadcasts
        rng = np.random.default_rng(3)
        L, F = 10, 150
        w = rng.uniform(0, 20, F).astype(np.float32)
        bl = rng.uniform(0, 30, F).astype(np.float32)
        rho = rng.uniform(0.1, 10, F).astype(np.float32)
        mask = (rng.random((L, F)) < 0.6).astype(np.float32)
        cap = rng.uniform(1, 50, L).astype(np.float32)
        kind = rng.integers(0, 2, L).astype(np.int32)
        dense = lambda v: np.broadcast_to(v[None, :], (L, F)).copy()
        out_v = np.asarray(waterfill_flows(w, bl, rho, mask, cap, kind,
                                           dt=0.5))
        out_d = np.asarray(waterfill(dense(w), dense(bl), dense(rho), mask,
                                     cap, kind, dt=0.5))
        np.testing.assert_allclose(out_v, out_d, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("block_flows", [128, 256])
    def test_block_flows_tiling_independence(self, block_flows):
        # chunked flow-axis traversal must not change the solve
        rng = np.random.default_rng(4)
        L, F = 8, 300
        w = rng.uniform(0, 20, (L, F)).astype(np.float32)
        bl = rng.uniform(0, 30, (L, F)).astype(np.float32)
        rho = rng.uniform(0.1, 10, (L, F)).astype(np.float32)
        mask = (rng.random((L, F)) < 0.7).astype(np.float32)
        cap = rng.uniform(1, 50, L).astype(np.float32)
        kind = rng.integers(0, 2, L).astype(np.int32)
        a = np.asarray(waterfill(w, bl, rho, mask, cap, kind, dt=1.0))
        b = np.asarray(waterfill(w, bl, rho, mask, cap, kind, dt=1.0,
                                 block_flows=block_flows))
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_padding_is_jit_cached(self):
        # repeat same-shape calls reuse the padded executable (the pad ops
        # trace once; no per-call un-jitted jnp.pad dispatch chain)
        from repro.kernels.waterfill.ops import _waterfill_padded

        rng = np.random.default_rng(5)
        L, F = 6, 37
        args = (rng.uniform(0, 5, (L, F)).astype(np.float32),
                rng.uniform(0, 5, (L, F)).astype(np.float32),
                rng.uniform(0.1, 5, (L, F)).astype(np.float32),
                np.ones((L, F), np.float32),
                rng.uniform(1, 9, L).astype(np.float32),
                np.zeros(L, np.int32))
        waterfill(*args, dt=1.0)
        size = _waterfill_padded._cache_size()
        for _ in range(3):
            waterfill(*args, dt=1.0)
        assert _waterfill_padded._cache_size() == size

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_parity_random(self, seed):
        # randomized (backlog, rho, mask, capacity): bisection == exact sort
        rng = np.random.default_rng(seed)
        L, F = int(rng.integers(1, 10)), int(rng.integers(1, 80))
        w = rng.uniform(0, 20, (L, F)).astype(np.float32)
        bl = rng.uniform(0, 30, (L, F)).astype(np.float32)
        rho = rng.uniform(0.05, 10, (L, F)).astype(np.float32)
        mask = (rng.random((L, F)) < 0.6).astype(np.float32)
        cap = rng.uniform(0.5, 50, L).astype(np.float32)
        kind = rng.integers(0, 2, L).astype(np.int32)
        dt = float(rng.choice([0.5, 1.0, 5.0]))
        out = np.asarray(waterfill(w, bl, rho, mask, cap, kind, dt=dt))
        ref = np.asarray(waterfill_reference(
            *(jnp.asarray(a) for a in (w, bl, rho, mask, cap, kind)), dt))
        np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)


# -------------------------------------------------------- flash attention
class TestFlashAttention:
    @pytest.mark.parametrize("B,S,T,H,K,hd", [
        (2, 128, 128, 4, 2, 64),
        (1, 256, 256, 8, 8, 32),
        (1, 128, 128, 6, 3, 64),     # non-pow2 head count (whisper-like)
        (2, 64, 64, 4, 1, 128),      # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, B, S, T, H, K, hd, causal):
        rng = np.random.default_rng(S * H)
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, T, K, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, T, K, hd)), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
        ref = flash_attention_reference(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), dtype)
        k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), dtype)
        v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), dtype)
        out = flash_attention(q, k, v, block_q=64, block_k=64)
        ref = flash_attention_reference(q, k, v)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol)
        assert out.dtype == dtype

    def test_block_shape_independence(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
        a = flash_attention(q, k, v, block_q=64, block_k=64)
        b = flash_attention(q, k, v, block_q=128, block_k=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- ssd scan
class TestSSDScan:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 128, 4, 32, 16, 32),
        (1, 256, 2, 64, 32, 64),
        (2, 64, 3, 16, 8, 64),
        (1, 128, 8, 64, 128, 128),   # mamba2-370m-like head
    ])
    def test_matches_sequential_reference(self, B, S, H, P, N, chunk):
        rng = np.random.default_rng(S + H)
        x = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
        y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
        yr, hr = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   rtol=1e-4, atol=1e-4)

    def test_chunk_independence(self):
        rng = np.random.default_rng(5)
        B, S, H, P, N = 1, 256, 2, 32, 16
        x = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
        y32, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=32)
        y128, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=128)
        np.testing.assert_allclose(np.asarray(y32), np.asarray(y128),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------- model block vs kernel oracle
def test_mamba2_block_matches_ssd_reference():
    """blocks.mamba2_forward's chunked jnp path must equal the sequential
    oracle when fed the same pre-activations (cross-check of the model)."""
    from repro.models.lm import ModelConfig
    from repro.models import blocks as Bl

    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab=16,
                      ssm_state=16, ssm_head_dim=16, ssm_expand=2,
                      dtype=jnp.float32, ssd_chunk=16)
    key = jax.random.PRNGKey(0)
    p = Bl.build_params(key, Bl.mamba2_specs(32, 16, 16, 2, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32)) * 0.3
    y1, _ = Bl.mamba2_forward(p, x, cfg, chunk=16)
    y2, _ = Bl.mamba2_forward(p, x, cfg, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
