"""Packed single-dispatch fleet runtime (PR 5).

The contract under test: a warm fleet run is ONE fused executable — every
bucket of the plan lives inside the same XLA program — and fusing the
dispatches changes *nothing*: results are bitwise-identical to dispatching
each bucket as its own executable (``fused=False``), for every policy, on
the canonical 44-scenario corpus (static and in-run-scheduled scenarios
mixed, including brute-force ``x_fixed`` studies whose rate vectors are
deliberately link-infeasible — the per-scenario enforcement mask keeps
their static members exactly on the static path). Plus the cache-isolation
and capacity-growth properties of the per-instance runner."""
import numpy as np
import pytest

from repro.net import big_switch, link_failure_schedule
from repro.streams import (
    FleetRunner,
    bench_fleet,
    compile_fleet,
    compile_sim,
    parallelize,
    round_robin,
    simulate,
    trending_topics,
)

SECONDS = 20.0
DT = 0.5


@pytest.fixture(scope="module")
def corpus():
    sims = compile_fleet(bench_fleet(seed=0))
    assert len(sims) == 44
    return sims


@pytest.fixture(scope="module")
def corpus_xf(corpus):
    # deliberately arbitrary (link-infeasible) brute-force rate vectors:
    # the regime the paper's motivation study sweeps, and the hard case
    # for packing static scenarios next to scheduled ones
    rng = np.random.default_rng(7)
    return [rng.uniform(0.2, 3.0, s.R.shape[0]).astype(np.float32)
            for s in corpus]


def _result_arrays(r):
    out = [r.sink_mb, r.sink_mb_app, r.latency, r.link_load]
    if r.caps_t is not None:
        out.append(r.caps_t)
    return out


class TestPackedVsPerBucketParity:
    """Fusing every bucket into one executable is a pure dispatch change:
    bitwise-identical SimResults, one kernel dispatch per run."""

    @pytest.mark.parametrize("policy", ["tcp", "appaware", "appfair",
                                        "fixed"])
    def test_bitwise_identical_on_corpus(self, corpus, corpus_xf, policy):
        kw = dict(x_fixed=corpus_xf) if policy == "fixed" else {}
        packed = FleetRunner(fused=True)
        per_bucket = FleetRunner(fused=False)
        a = packed.run(corpus, policy, seconds=SECONDS, dt=DT, **kw)
        b = per_bucket.run(corpus, policy, seconds=SECONDS, dt=DT, **kw)
        assert packed.last_stats["n_dispatches"] == 1
        assert (per_bucket.last_stats["n_dispatches"]
                == per_bucket.last_stats["n_buckets"])
        for ra, rb in zip(a, b):
            for x, y in zip(_result_arrays(ra), _result_arrays(rb)):
                np.testing.assert_array_equal(x, y)
            assert np.isfinite(ra.sink_mb).all()
            assert np.isfinite(ra.latency).all()

    def test_packed_matches_per_scenario_simulate(self, corpus):
        # end-to-end parity against the unpadded single-scenario path
        # (padding re-associates some XLA reductions, so this is the
        # element-wise tolerance contract, not the bitwise one)
        runner = FleetRunner(fused=True)
        batch = runner.run(corpus[:8], "tcp", seconds=SECONDS, dt=DT)
        for sim, rb in zip(corpus[:8], batch):
            ref = simulate(sim, "tcp", seconds=SECONDS, dt=DT)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)
            np.testing.assert_allclose(rb.latency, ref.latency,
                                       rtol=1e-4, atol=1e-3)


class TestSingleDispatch:
    def test_heterogeneous_apps_still_one_dispatch(self):
        # appfair buckets by exact app count — but every bucket lives in
        # the same executable, so mixed-app fleets are still one dispatch
        def two_app(n_apps, cap):
            g = parallelize(trending_topics(), seed=0)
            app_of_inst = (np.arange(g.n_instances) % n_apps).astype(
                np.int32)
            return compile_sim(g, big_switch(8, cap), round_robin(g, 8),
                               app_of_inst=app_of_inst, n_apps=n_apps)

        sims = [two_app(2, 1.25), two_app(3, 1.875), two_app(2, 2.5)]
        runner = FleetRunner(fused=True)
        batch = runner.run(sims, "appfair", seconds=SECONDS, dt=DT)
        assert runner.last_stats["n_dispatches"] == 1
        assert runner.last_stats["n_buckets"] == 2  # one per app count
        for sim, rb in zip(sims, batch):
            ref = simulate(sim, "appfair", seconds=SECONDS, dt=DT)
            np.testing.assert_allclose(rb.sink_mb, ref.sink_mb, atol=1e-4)

    def test_overhead_aware_planner_collapses_cheap_ticks(self, corpus):
        # no solver in the scan -> per-bucket tick overhead dominates any
        # padded-FLOP waste and the planner merges below the cap; the
        # solver-heavy tcp fleet keeps tighter buckets under the same cap
        runner = FleetRunner(fused=True)
        fixed_plan = runner.plan(corpus, "fixed")
        tcp_plan = runner.plan(corpus, "tcp")
        assert len(fixed_plan) < len(tcp_plan) <= runner.max_buckets


class TestEnforcementMask:
    """A static scenario with a deliberately link-infeasible x_fixed keeps
    its exact static semantics when packed next to a scheduled scenario —
    the per-scenario enforcement gate, which replaced PR 3's split_sched
    bucketing carve-out."""

    def _static_and_scheduled(self):
        g = parallelize(trending_topics(), seed=0)
        topo = big_switch(8, 1.25)
        static = compile_sim(g, topo, round_robin(g, 8))
        sched = link_failure_schedule(topo, [0, 1], 5.0, 10.0, degrade=0.1)
        dyn = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        return g, static, dyn

    def test_infeasible_fixed_static_exact_in_scheduled_bucket(self):
        g, static, dyn = self._static_and_scheduled()
        # 10x the per-link capacity: grossly infeasible on purpose
        x = np.full(g.n_flows, 12.5, np.float32)
        runner = FleetRunner(fused=True)
        batch = runner.run([static, dyn], "fixed", seconds=SECONDS, dt=DT,
                           x_fixed=[x, x])
        assert runner.last_stats["n_buckets"] == 1  # they DO share a bucket
        ref = simulate(static, "fixed", seconds=SECONDS, dt=DT, x_fixed=x)
        np.testing.assert_allclose(batch[0].sink_mb, ref.sink_mb, atol=1e-5)
        np.testing.assert_allclose(batch[0].link_load, ref.link_load,
                                   atol=1e-5)
        # ... while the scheduled member's network DOES enforce caps(t)
        ref_dyn = simulate(dyn, "fixed", seconds=SECONDS, dt=DT, x_fixed=x)
        np.testing.assert_allclose(batch[1].sink_mb, ref_dyn.sink_mb,
                                   atol=1e-5)
        np.testing.assert_allclose(batch[1].caps_t, ref_dyn.caps_t,
                                   atol=1e-6)


class TestPerRunnerCaches:
    """Regression for the PR 4 @staticmethod-over-global-state cache:
    executable and plan caches are per-instance, so two runners with
    different knobs cannot poison each other's entries or assertions."""

    def test_compile_cache_isolated_between_runners(self, corpus):
        a = FleetRunner(max_buckets=2)
        a.run(corpus[:4], "tcp", seconds=5.0, dt=DT)
        size_a = a.compile_cache_size()
        assert size_a > 0
        # a second runner with a different plan compiles its own programs
        b = FleetRunner(max_buckets=1)
        assert b.compile_cache_size() == 0
        b.run(corpus[:4], "tcp", seconds=5.0, dt=DT)
        assert b.compile_cache_size() > 0
        # ... and none of them leaked into runner a's count
        assert a.compile_cache_size() == size_a
        out = a.run(corpus[:4], "tcp", seconds=5.0, dt=DT)
        assert a.compile_cache_size() == size_a  # still no recompile
        assert all(r is not None for r in out)

    def test_plan_cache_isolated_between_runners(self, corpus):
        a = FleetRunner(max_buckets=4, tick_overhead=0.0)
        b = FleetRunner(max_buckets=1)
        plan_a = a.plan(corpus, "tcp")
        plan_b = b.plan(corpus, "tcp")
        assert len(plan_a) == 4 and len(plan_b) == 1
        # re-planning returns each runner's own cached plan, unchanged
        assert a.plan(corpus, "tcp") is plan_a
        assert b.plan(corpus, "tcp") is plan_b


class TestCapacityGrowth:
    """Bucket rows are rounded up to a small capacity quantum: a fleet
    that grows only in scenario count within the padded capacity reuses
    its compiled executable (the spare rows were inert scenarios)."""

    def _fleet(self, n):
        g = parallelize(trending_topics(), seed=0)
        return [compile_sim(g, big_switch(8, 1.0 + 0.05 * i),
                            round_robin(g, 8)) for i in range(n)]

    def test_growth_within_capacity_reuses_executable(self):
        sims = self._fleet(18)            # rows round to 20: headroom 2
        runner = FleetRunner(fused=True)
        out = runner.run(sims, "tcp", seconds=10.0, dt=DT)
        assert runner.last_stats["rows"] == [20]
        size = runner.compile_cache_size()
        grown = sims + self._fleet(20)[18:]   # +2 scenarios, same shape
        out2 = runner.run(grown, "tcp", seconds=10.0, dt=DT)
        assert runner.last_stats["rows"] == [20]
        assert runner.compile_cache_size() == size  # no recompile
        # prefix results identical, new members correct
        for a, b in zip(out, out2[:18]):
            np.testing.assert_array_equal(a.sink_mb, b.sink_mb)
        ref = simulate(grown[-1], "tcp", seconds=10.0, dt=DT)
        np.testing.assert_allclose(out2[-1].sink_mb, ref.sink_mb, atol=1e-4)

    def test_inert_spare_rows_are_harmless(self):
        # 17 scenarios -> 20 rows: three spare rows run as inert
        # scenarios; every real result stays finite and correct
        sims = self._fleet(17)
        runner = FleetRunner(fused=True)
        out = runner.run(sims, "appaware", seconds=10.0, dt=DT)
        assert runner.last_stats["rows"] == [20]
        ref = simulate(sims[3], "appaware", seconds=10.0, dt=DT)
        np.testing.assert_allclose(out[3].sink_mb, ref.sink_mb, atol=1e-4)
        for r in out:
            assert np.isfinite(r.sink_mb).all()
            assert np.isfinite(r.latency).all()


class TestStagingFingerprint:
    """The staging-reuse fingerprint must cover field *content*, not just
    scenario object identity: CompiledSim is a plain (non-frozen)
    dataclass, so a caller can legally mutate a scenario's arrays in
    place between warm calls — the runner must restage, not replay the
    pre-mutation fleet from its buffers."""

    def test_inplace_mutation_restages(self):
        g = parallelize(trending_topics(), seed=0)
        sims = [compile_sim(g, big_switch(8, 1.0 + 0.1 * i),
                            round_robin(g, 8)) for i in range(3)]
        # re-back one scenario's gen_rate with a mutable numpy array — the
        # scenario OBJECT stays the same across both runs
        gen = np.asarray(sims[1].gen_rate).copy()
        sims[1].gen_rate = gen
        runner = FleetRunner(fused=True)
        out1 = runner.run(sims, "tcp", seconds=10.0, dt=DT)
        assert "order_rebuilds" in runner.last_stats
        # starve the sources (scaling UP would be invisible in sink_mb on
        # this bandwidth-bound corpus); in-place: identity check is blind
        gen *= 0.05
        out2 = runner.run(sims, "tcp", seconds=10.0, dt=DT)
        # the mutated scenario must reflect its new generation rate ...
        ref = simulate(sims[1], "tcp", seconds=10.0, dt=DT)
        np.testing.assert_allclose(out2[1].sink_mb, ref.sink_mb, atol=1e-4)
        assert not np.allclose(out1[1].sink_mb, out2[1].sink_mb)
        # ... while untouched scenarios reproduce bitwise
        np.testing.assert_array_equal(out1[0].sink_mb, out2[0].sink_mb)
        np.testing.assert_array_equal(out1[2].sink_mb, out2[2].sink_mb)

    def test_unmutated_warm_call_still_reuses_staging(self):
        g = parallelize(trending_topics(), seed=0)
        sims = [compile_sim(g, big_switch(8, 1.0 + 0.1 * i),
                            round_robin(g, 8)) for i in range(3)]
        runner = FleetRunner(fused=True)
        out1 = runner.run(sims, "tcp", seconds=10.0, dt=DT)
        size = runner.compile_cache_size()
        out2 = runner.run(sims, "tcp", seconds=10.0, dt=DT)
        assert runner.compile_cache_size() == size
        for a, b in zip(out1, out2):
            np.testing.assert_array_equal(a.sink_mb, b.sink_mb)
