"""Distribution tests: sharding policy resolution, HLO collective parsing,
and dry-run-lite — an 8-device (subprocess) lower+compile of train/prefill/
decode on a 2x4 mesh for representative families."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.launch import hlo_stats
from repro.launch.mesh import _mk
from repro.sharding import policy as pol


class TestPolicy:
    def _mesh(self):
        return _mk((1, 1), ("data", "model"))

    def test_spec_resolution_and_dedup(self):
        with pol.sharding_policy(self._mesh()):
            spec = pol.spec_for("batch", "seq", "heads", None)
            # batch -> ("pod","data") filtered to ("data",); heads -> model
            assert spec[0] in ("data", ("data",))
            assert spec[2] == "model"
            # duplicate mesh axis is dropped for later logical axes
            spec2 = pol.spec_for("kv_seq", "kv_heads")
            assert spec2[0] == "model" and spec2[1] is None

    def test_missing_mesh_axes_dropped(self):
        with pol.sharding_policy(self._mesh()):
            # "pod" doesn't exist on a single-pod mesh
            spec = pol.spec_for("batch")
            assert spec[0] in ("data", ("data",))

    def test_noop_outside_context(self):
        import jax.numpy as jnp
        x = jnp.ones((4, 4))
        assert pol.shard_as(x, "batch", "embed") is x
        assert pol.shard_count("batch") == 1

    def test_divisibility_guard(self):
        mesh = _mk((1, 1), ("data", "model"))
        sh = pol.param_sharding(mesh, ("vocab", "embed"), (7, 8))
        # vocab=7 not divisible by model-size 1? size-1 always divides; spec kept
        assert sh.spec[1] is not None or sh.spec[0] is not None


class TestHloStats:
    HLO = textwrap.dedent("""\
      %all-reduce.1 = f32[16,512]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
      %ag = bf16[64,1024]{1,0} all-gather(%y), channel_id=2, replica_groups=[8,32]<=[256], dimensions={0}
      %rs = f32[4,256]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[2,4]<=[8], to_apply=%add
      %cp = u8[1000]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
      %ar2 = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), channel_id=5, replica_groups=[4,4]<=[16], to_apply=%add
      %notacoll = f32[2,2]{1,0} add(%p, %q)
    """)

    def test_parse(self):
        st = hlo_stats.collective_stats(self.HLO)
        assert st["count"] == 5
        assert st["all-reduce"] == 16 * 512 * 4 + 2 * 8 * 4
        # all-gather operand = result / group size (32)
        assert st["all-gather"] == 64 * 1024 * 2 // 32
        # reduce-scatter operand = result * group size (4)
        assert st["reduce-scatter"] == 4 * 256 * 4 * 4
        assert st["collective-permute"] == 1000
        assert st["total"] == sum(
            st[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "collective-permute"))

    def test_ignores_done(self):
        txt = ("%s = f32[8]{0} all-reduce-start(%x), replica_groups=[2,2]<=[4]\n"
               "%d = f32[8]{0} all-reduce-done(%s)\n")
        st = hlo_stats.collective_stats(txt)
        assert st["count"] == 1


_SUBPROC = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp
    from repro.launch.mesh import _mk
    from repro.launch.shardings import (batch_shardings, opt_shardings,
                                        param_shardings, cache_shardings,
                                        replicated)
    from repro.models.registry import ShapeSpec, get_config, get_model
    from repro.sharding.policy import sharding_policy
    from repro.train.optim import AdamW
    from repro.train.step import make_train_step
    from repro.launch import hlo_stats

    arch = {arch!r}
    cfg = get_config(arch).reduced(d_model=128, vocab=1024,
                                   n_heads=8, n_kv_heads=8, head_dim=None)
    api = get_model(cfg)
    mesh = _mk((2, 4), ("data", "model"))
    out = {{}}
    with sharding_policy(mesh):
        # train
        spec = ShapeSpec("t", 256, 8, "train")
        opt = AdamW(lr=1e-3)
        step = make_train_step(api, opt)
        pab = api.abstract_params()
        oab = jax.eval_shape(opt.init, pab)
        psh = param_shardings(mesh, api)
        isp = api.input_specs(spec)
        c = jax.jit(step, in_shardings=(psh, opt_shardings(mesh, psh, oab),
                                        batch_shardings(mesh, isp))
                    ).lower(pab, oab, isp).compile()
        st = hlo_stats.collective_stats(c.as_text())
        out["train_collectives"] = st["count"]
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax<=0.4.x returns [dict]
            ca = ca[0] if ca else {{}}
        out["train_flops"] = float(ca.get("flops", 0))
        # decode
        dspec = ShapeSpec("d", 64, 8, "decode")
        cab = jax.eval_shape(lambda: api.init_cache(8, 64))
        csh = cache_shardings(mesh, cab)
        dfn = lambda p, cache, t, pos: api.decode(p, cache, t, pos)
        c2 = jax.jit(dfn, in_shardings=(
            psh, csh,
            batch_shardings(mesh, {{"tokens": api.input_specs(dspec)["tokens"]}})["tokens"],
            replicated(mesh))).lower(
            pab, cab, api.input_specs(dspec)["tokens"],
            jax.ShapeDtypeStruct((), jnp.int32)).compile()
        out["decode_ok"] = True
    print(json.dumps(out))
""")


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "dbrx-132b", "mamba2-370m"])
def test_dryrun_lite_8dev(arch):
    """Compile a reduced config on a faked 8-device 2x4 mesh in a subprocess
    (device count must be set before jax initializes)."""
    code = _SUBPROC.format(arch=arch)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root",
                            # host-platform device faking is a CPU feature;
                            # never probe for TPUs from the bare subprocess
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["train_collectives"] > 0, "SPMD produced no collectives?"
    assert out["train_flops"] > 0
    assert out["decode_ok"]
