"""Property-test harness for the fused fixed-trip max-min solver.

Three layers of evidence that `maxmin_fused` is the exact demand-limited
max-min allocation:

  1. parity ≤ 1e-5 against the retained oracles on randomized [F, L]
     instances — the plain-numpy sequential progressive fill
     (`demand_limited_maxmin_np`, unbounded rounds) and the while-loop
     progressive-filling oracle (`demand_limited_maxmin`, bisection-based
     per-link levels — independent math from the fused solver), which
     both satisfy the KKT certificate *unconditionally* (the former
     clamp-and-resolve oracle did not: seed 5041, pinned below);
  2. the max-min optimality KKT invariant checked *directly* on the fused
     solver's output: every flow is either demand-capped or crosses a
     saturated link on which no flow has a greater rate;
  3. the FILL_ROUNDS default is exact on seed-corpus routing structure:
     the bottleneck-level chain there is ≤ 3 deep, exactly what the
     default 2 rounds + closing sweep resolve (``rounds=None`` stays the
     provably exact bound).

Edge cases pinned explicitly: zero demand, single flow, off-net flows,
zero-capacity links, all-one-level instances.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.core.tcp import (
    demand_limited_maxmin,
    demand_limited_maxmin_np,
    maxmin_fused,
    maxmin_rates,
)

ATOL = 1e-5


def _instance(seed: int, F: int, L: int, links_per_flow: int,
              zero_cap: bool, zero_demand: bool, off_net: bool):
    """Random routing/capacity/demand instance with optional degeneracies."""
    rng = np.random.default_rng(seed)
    R = np.zeros((F, L), np.float32)
    for f in range(F):
        k = int(rng.integers(0 if off_net else 1,
                             min(L, links_per_flow) + 1))
        if k:
            R[f, rng.choice(L, k, replace=False)] = 1.0
    cap = rng.uniform(0.5, 20.0, L).astype(np.float32)
    if zero_cap:
        cap[rng.integers(0, L)] = 0.0
    d = rng.uniform(0.0, 10.0, F).astype(np.float32)
    if zero_demand:
        d[rng.integers(0, F)] = 0.0
    return R, cap, d


def _assert_maxmin_invariant(R, cap, d, x, tol=1e-4):
    """KKT certificate of demand-limited max-min optimality:

      * feasible: no link is oversubscribed and 0 ≤ x_f ≤ d_f;
      * off-net flows get exactly their demand (unconstrained);
      * every on-net flow is either demand-capped, or crosses a saturated
        link where no flow has a greater rate (its bottleneck).
    """
    x = np.asarray(x, np.float64)
    load = x @ R
    scale = max(float(cap.max(initial=1.0)), 1.0)
    assert np.all(load <= cap + tol * scale), (load - cap).max()
    assert np.all(x >= -tol)
    on_net = R.sum(1) > 0
    np.testing.assert_allclose(x[~on_net], d[~on_net], atol=tol)
    assert np.all(x[on_net] <= d[on_net] + tol * np.maximum(d[on_net], 1.0))
    saturated = load >= cap - tol * np.maximum(cap, 1.0)
    for f in np.nonzero(on_net)[0]:
        if x[f] >= d[f] - tol * max(d[f], 1.0):
            continue  # demand-capped
        links = np.nonzero((R[f] > 0) & saturated)[0]
        assert links.size, f"flow {f}: below demand but no saturated link"
        # bottleneck: some saturated link where f's rate is maximal
        ok = any(
            x[f] >= x[R[:, link] > 0].max() - tol * max(1.0, x.max())
            for link in links
        )
        assert ok, f"flow {f}: rate {x[f]} not maximal on any saturated link"


def _fused(R, cap, d, rounds="default"):
    kw = {} if rounds == "default" else {"rounds": rounds}
    return np.asarray(
        maxmin_fused(jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d), **kw))


class TestFusedParity:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000),
           F=st.integers(1, 28), L=st.integers(1, 12),
           links_per_flow=st.integers(1, 4),
           zero_cap=st.booleans(), zero_demand=st.booleans(),
           off_net=st.booleans())
    def test_matches_numpy_reference(self, seed, F, L, links_per_flow,
                                     zero_cap, zero_demand, off_net):
        R, cap, d = _instance(seed, F, L, links_per_flow,
                              zero_cap, zero_demand, off_net)
        ref = demand_limited_maxmin_np(R, cap, d)
        got = _fused(R, cap, d, rounds=None)
        np.testing.assert_allclose(got, ref, atol=ATOL * 10, rtol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_matches_while_loop_oracle(self, seed):
        # The while-loop oracle is true progressive filling (freeze sated
        # flows, else the global-minimum bottleneck level; per-link levels
        # by bisection), so it lands on the max-min point on EVERY
        # instance: the fused solver must match it unconditionally, and
        # the oracle's own output must pass the KKT certificate. (Its
        # predecessor — clamp-at-demand-and-resolve — converged to a
        # feasible non-max-min fixed point on rare instances, e.g. seed
        # 5041 of this draw, and this assertion was gated on the oracle
        # agreeing with the numpy reference. The gate is gone.)
        R, cap, d = _instance(seed, 16, 6, 3, False, False, True)
        ref = demand_limited_maxmin_np(R, cap, d)
        got = _fused(R, cap, d, rounds=None)
        np.testing.assert_allclose(got, ref, atol=ATOL * 10, rtol=1e-5)
        oracle = np.asarray(demand_limited_maxmin(
            jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d)))
        np.testing.assert_allclose(got, oracle, atol=ATOL * 10, rtol=1e-5)
        _assert_maxmin_invariant(R, cap, d, oracle)

    def test_seed_5041_oracle_is_maxmin(self):
        # regression pin for the clamp-and-resolve defect: flow 15's
        # demand-free max-min share (1.615) covered its demand (1.458) at
        # round 0, so the old oracle froze it at demand — but demand caps
        # elsewhere raise its link-3 competitors in the true optimum,
        # where its level is 1.423 < demand. Progressive filling gets it.
        R, cap, d = _instance(5041, 16, 6, 3, False, False, True)
        ref = demand_limited_maxmin_np(R, cap, d)
        oracle = np.asarray(demand_limited_maxmin(
            jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d)))
        np.testing.assert_allclose(oracle, ref, atol=ATOL * 10, rtol=1e-5)
        _assert_maxmin_invariant(R, cap, d, oracle)

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000),
           F=st.integers(1, 28), L=st.integers(1, 12),
           links_per_flow=st.integers(1, 4),
           zero_cap=st.booleans(), zero_demand=st.booleans(),
           off_net=st.booleans())
    def test_optimality_invariant(self, seed, F, L, links_per_flow,
                                  zero_cap, zero_demand, off_net):
        R, cap, d = _instance(seed, F, L, links_per_flow,
                              zero_cap, zero_demand, off_net)
        x = _fused(R, cap, d, rounds=None)
        _assert_maxmin_invariant(R, cap, d, x)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_default_rounds_always_feasible(self, seed):
        # FILL_ROUNDS may in principle truncate a deep level chain; the
        # closing sweep must still never oversubscribe any link
        R, cap, d = _instance(seed, 28, 12, 4, True, True, True)
        x = _fused(R, cap, d)
        load = x @ R
        assert np.all(load <= cap + 1e-4 * np.maximum(cap, 1.0))
        on_net = R.sum(1) > 0
        assert np.all(x[on_net] <= d[on_net] + 1e-4)


class TestEdgeCases:
    def test_zero_demand_all(self):
        R = np.ones((4, 2), np.float32)
        x = _fused(R, np.full(2, 5.0, np.float32), np.zeros(4, np.float32))
        np.testing.assert_allclose(x, 0.0, atol=ATOL)

    def test_single_flow(self):
        R = np.array([[1.0, 0.0, 1.0]], np.float32)
        cap = np.array([3.0, 1.0, 7.0], np.float32)
        # capped by the tightest link it crosses
        assert _fused(R, cap, np.array([9.0], np.float32))[0] == (
            pytest.approx(3.0, abs=ATOL))
        # or by its own demand
        assert _fused(R, cap, np.array([2.0], np.float32))[0] == (
            pytest.approx(2.0, abs=ATOL))

    def test_off_net_flows_get_demand(self):
        R = np.array([[1.0], [0.0]], np.float32)
        x = _fused(R, np.array([1.0], np.float32),
                   np.array([9.0, 4.0], np.float32))
        np.testing.assert_allclose(x, [1.0, 4.0], atol=ATOL)

    def test_zero_capacity_link(self):
        R = np.array([[1.0, 1.0], [0.0, 1.0]], np.float32)
        cap = np.array([0.0, 5.0], np.float32)
        x = _fused(R, cap, np.array([3.0, 3.0], np.float32))
        np.testing.assert_allclose(x, [0.0, 3.0], atol=ATOL)

    def test_all_one_level(self):
        # everyone shares one bottleneck with slack demand: equal split
        F = 6
        R = np.ones((F, 1), np.float32)
        x = _fused(R, np.array([3.0], np.float32),
                   np.full(F, 10.0, np.float32))
        np.testing.assert_allclose(x, 3.0 / F, atol=ATOL)
        # ... and converges in ONE round + closing sweep
        x1 = _fused(R, np.array([3.0], np.float32),
                    np.full(F, 10.0, np.float32), rounds=1)
        np.testing.assert_allclose(x1, 3.0 / F, atol=ATOL)

    def test_demandless_matches_maxmin_rates_oracle(self):
        # slack demands reduce the fused fill to plain max-min: compare
        # with the retained while-loop oracle where it is finite
        R, cap, _ = _instance(3, 12, 5, 3, False, False, False)
        oracle = np.asarray(maxmin_rates(jnp.asarray(R), jnp.asarray(cap)))
        bound = float(cap.sum()) + 1.0
        got = _fused(R, cap, np.full(12, bound, np.float32))
        fin = np.isfinite(oracle)
        np.testing.assert_allclose(got[fin], oracle[fin], atol=1e-4,
                                   rtol=1e-5)


class TestCorpusRounds:
    """Backs the FILL_ROUNDS=2 static bound: on seed-corpus routing
    structure the bottleneck-level chain is ≤ 3 deep, and 2 rounds + the
    closing sweep resolve exactly 3 levels — the shipped default already
    reproduces the provably exact ``rounds=None`` bound across randomized
    demand draws."""

    def test_default_rounds_exact_on_corpus(self):
        from repro.core.tcp import FILL_ROUNDS
        from repro.streams import compile_fleet, seed_fleet

        sims = compile_fleet(seed_fleet(seed=0))[::3]  # every 3rd: 10 sims
        rng = np.random.default_rng(0)
        for sim in sims:
            R = np.asarray(sim.R)
            cap = np.asarray(sim.caps)
            for _ in range(4):
                d = rng.uniform(0.0, 2.0 * cap.max(),
                                R.shape[0]).astype(np.float32)
                exact = _fused(R, cap, d, rounds=None)
                got = _fused(R, cap, d, rounds=FILL_ROUNDS)
                np.testing.assert_allclose(got, exact, atol=ATOL,
                                           rtol=1e-5)
                _assert_maxmin_invariant(R, cap, d, exact)

    def test_policy_path_parity_with_while_oracle(self):
        """End-to-end: 40 ticks of the tcp per-tick loop (`_tick` + demand
        clamp) once with the fused solver and once with the fully-converged
        while-loop oracle produce the same trajectory on a seed scenario —
        the fused solver is a drop-in for the policy hot path, not just a
        per-solve match."""
        import jax.numpy as jnp

        from repro.streams import compile_fleet, seed_fleet
        from repro.streams.simulator import INTERNAL_RATE, _tick

        sim = compile_fleet(seed_fleet(seed=0))[0]
        F = sim.R.shape[0]
        dt, qcap = 0.5, 8.0

        def run(solver):
            Qs = Qr = jnp.zeros((F,), jnp.float32)
            prod = drain_e = jnp.zeros((F,), jnp.float32)
            sinks = []
            for _ in range(40):
                demand = jnp.minimum(
                    Qs / dt + prod,
                    jnp.maximum(qcap - Qr, 0.0) / dt + drain_e)
                x = solver(sim.R, sim.caps, demand)
                x = jnp.where(sim.has_links, jnp.minimum(x, demand),
                              INTERNAL_RATE)
                Qs, Qr, transfer, drain, (sink, _, _, _) = _tick(
                    sim, Qs, Qr, x, dt, qcap)
                t_in = sim.M_in @ transfer
                out_i = sim.selectivity * t_in + sim.gen_rate * dt
                prod = out_i[sim.src_of_flow] * sim.w_of_flow / dt
                drain_e = 0.5 * drain_e + 0.5 * drain
                sinks.append(float(sink))
            return np.asarray(sinks)

        fused = run(maxmin_fused)
        oracle = run(demand_limited_maxmin)
        np.testing.assert_allclose(fused, oracle, atol=1e-4)
