"""Multi-device campaign sharding (PR 8).

The contract: with the chunk stream sharded across N emulated host
devices (``--xla_force_host_platform_device_count``), campaign metrics
are **bitwise-identical** to the 1-device streamed path and to the
materialized oracle — chunk row quantization is device-count-independent,
so the shard changes *where* a chunk runs, never what it computes. The
corpus size (54) deliberately does not divide the device count (4): the
round-robin stream assignment must handle the ragged tail.

The 4-device half runs in a subprocess because the device count is baked
into XLA at jax import time; the child writes its campaign metrics per
policy to .npy files and the parent (1 stream, ``shard=False``) compares
bitwise. In-child invariants: campaign == unsharded materialized oracle,
repeat call bitwise-stable with a flat compile cache, host staging
bounded by the three rotating slots per stream, and all four devices
actually used.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SECONDS = 8.0
DT = 0.5
N_SCEN = 54          # not divisible by the 4 emulated devices
CHUNK_ROWS = 16      # 27-member buckets -> 2 chunks each -> 4 streams
POLICIES = ("tcp", "appaware", "appfair", "fixed")

_CHILD = r"""
import json, sys
import numpy as np
out_dir = sys.argv[1]
seconds, dt, n_scen, chunk_rows = (float(sys.argv[2]), float(sys.argv[3]),
                                   int(sys.argv[4]), int(sys.argv[5]))
import jax
assert jax.local_device_count() == 4, jax.local_device_count()
from repro.streams import campaign_fleet, compile_fleet
from repro.streams.fleet import FleetRunner

sims = compile_fleet(campaign_fleet(n_scen, seed=0))
xf = [np.full(s.R.shape[0], 0.25, np.float32) for s in sims]
runner = FleetRunner()
info = {}
for policy in %(policies)r:
    kw = dict(x_fixed=xf) if policy == "fixed" else {}
    cr = runner.run_campaign(sims, policy, seconds=seconds, dt=dt,
                             chunk_rows=chunk_rows, **kw)
    st = dict(runner.last_stats)
    # sharded campaign == the unsharded materialized oracle, bitwise
    oracle = np.stack([r.metrics for r in
                       runner.run(sims, policy, seconds=seconds, dt=dt,
                                  shard=False, **kw)])
    np.testing.assert_array_equal(cr.metrics, oracle)
    # repeat is bitwise-stable and compiles nothing new
    n0 = runner.compile_cache_size()
    cr2 = runner.run_campaign(sims, policy, seconds=seconds, dt=dt,
                              chunk_rows=chunk_rows, **kw)
    assert runner.compile_cache_size() == n0
    np.testing.assert_array_equal(cr.metrics, cr2.metrics)
    assert st["peak_staged_rows"] <= 3 * st["chunk_rows"] * st["n_streams"]
    np.save(f"{out_dir}/m4_{policy}.npy", cr.metrics)
    info[policy] = {"n_streams": st["n_streams"],
                    "n_chunks": st["n_chunks"],
                    "transfer_s": st["transfer_s"],
                    "peak_staged_rows": st["peak_staged_rows"],
                    "chunk_rows": st["chunk_rows"]}
# the known SPMD sensitivity of the batch-sharded `run` path: sharding a
# bucket's scenario axis re-associates exactly one epilogue reduction —
# total_sink_mb, the only full-length un-normalized sum — by at most 1 ULP;
# trajectories and every other metric stay bitwise (see the
# `_metrics_epilogue` docstring). Recorded here, asserted by the parent.
from repro.streams.simulator import metric_index
ulp = {}
for policy in ("tcp", "appaware"):
    sh = runner.run(sims, policy, seconds=seconds, dt=dt, shard=True)
    un = runner.run(sims, policy, seconds=seconds, dt=dt, shard=False)
    traj_equal = all(
        np.array_equal(a.sink_mb, b.sink_mb)
        and np.array_equal(a.link_load, b.link_load)
        and np.array_equal(a.latency, b.latency)
        for a, b in zip(sh, un))
    ms = np.stack([r.metrics for r in sh])
    mu = np.stack([r.metrics for r in un])
    diff_cols = sorted(set(np.nonzero(ms != mu)[1].tolist()))
    max_ulp = int(np.abs(ms.view(np.int32).astype(np.int64)
                         - mu.view(np.int32).astype(np.int64)).max())
    ulp[policy] = {"traj_equal": bool(traj_equal),
                   "diff_cols": [int(c) for c in diff_cols],
                   "max_ulp": max_ulp}
info["ulp_pin"] = {"sink_col": metric_index("total_sink_mb"),
                   "policies": ulp}
with open(f"{out_dir}/stats.json", "w") as f:
    json.dump(info, f)
print("CHILD_OK")
""" % {"policies": POLICIES}


@pytest.fixture(scope="module")
def four_device_run(tmp_path_factory):
    out = tmp_path_factory.mktemp("m4")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env.setdefault("REPRO_SMOKE", "1")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(out), str(SECONDS), str(DT),
         str(N_SCEN), str(CHUNK_ROWS)],
        capture_output=True, text=True, timeout=540, env=env)
    assert proc.returncode == 0, (proc.stdout, proc.stderr)
    assert "CHILD_OK" in proc.stdout
    with open(out / "stats.json") as f:
        stats = json.load(f)
    return out, stats


class TestShardedCampaignParity:
    def test_bitwise_equal_to_one_device_stream(self, four_device_run):
        out, _ = four_device_run
        from repro.streams import campaign_fleet, compile_fleet
        from repro.streams.fleet import FleetRunner

        sims = compile_fleet(campaign_fleet(N_SCEN, seed=0))
        xf = [np.full(s.R.shape[0], 0.25, np.float32) for s in sims]
        runner = FleetRunner()
        for policy in POLICIES:
            kw = dict(x_fixed=xf) if policy == "fixed" else {}
            # shard=False pins one stream regardless of this process's
            # own device count (the CI 4-device leg runs the whole suite
            # under the XLA flag)
            cr = runner.run_campaign(sims, policy, seconds=SECONDS, dt=DT,
                                     chunk_rows=CHUNK_ROWS, shard=False,
                                     **kw)
            assert runner.last_stats["n_streams"] == 1
            m4 = np.load(out / f"m4_{policy}.npy")
            np.testing.assert_array_equal(cr.metrics, m4)

    def test_all_devices_used(self, four_device_run):
        _, stats = four_device_run
        for policy in POLICIES:
            st = stats[policy]
            # >= 4 chunks stream through (appfair's exact-app buckets
            # chunk differently than tcp's), so all 4 emulated devices
            # get a stream
            assert st["n_streams"] == 4, st
            assert st["n_chunks"] >= 4, st
            assert st["transfer_s"] > 0.0

    def test_staging_bound_holds_when_sharded(self, four_device_run):
        _, stats = four_device_run
        for policy in POLICIES:
            st = stats[policy]
            assert (st["peak_staged_rows"]
                    <= 3 * st["chunk_rows"] * st["n_streams"])

    def test_sharded_run_drift_confined_to_total_sink_mb(
            self, four_device_run):
        """Pin the one tolerated SPMD sensitivity of the materialized
        ``run`` path: with the bucket's scenario axis sharded over 4
        devices, trajectories are bitwise-equal to the unsharded run and
        the epilogue metrics differ — if at all — only in the
        ``total_sink_mb`` column, by a couple of ULP (observed ≤ 2 on the
        54-scenario corpus). Anything wider (a new drifting op, a larger
        drift, a drifting trajectory) is a regression, not more of the
        same."""
        _, stats = four_device_run
        pin = stats["ulp_pin"]
        sink_col = pin["sink_col"]
        for policy, rec in pin["policies"].items():
            assert rec["traj_equal"], policy
            assert set(rec["diff_cols"]) <= {sink_col}, (policy, rec)
            assert rec["max_ulp"] <= 4, (policy, rec)
