"""Per-architecture smoke tests (assignment requirement): each of the ten
assigned archs is instantiated at a REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and finiteness.
Also checks prefill+decode consistency against the full forward (teacher
forcing) on representative families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLM
from repro.models.registry import get_config, get_model, list_archs
from repro.train.optim import AdamW
from repro.train.step import make_train_step

ARCHS = [
    "internvl2-1b", "dbrx-132b", "qwen3-moe-235b-a22b", "mamba2-370m",
    "whisper-tiny", "zamba2-1.2b", "qwen1.5-0.5b", "starcoder2-15b",
    "stablelm-1.6b", "yi-6b",
]

B, S = 2, 64


def _batch(cfg, key=0):
    pipe = SyntheticLM(vocab=cfg.vocab, seq_len=S, global_batch=B, seed=key)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    if cfg.family == "vlm":
        batch["vis_embeds"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.n_vis_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(8), (B, 32, cfg.d_model))
    return batch


def test_all_assigned_archs_registered():
    assert set(ARCHS) <= set(list_archs())


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits, aux = api.forward(params, batch)
    exp_len = S + (cfg.n_vis_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(api, opt))
    opt_state = opt.init(params)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually changed
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree_util.tree_leaves(diff)) > 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_step(name):
    cfg = get_config(name).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(B, max_len=32)
    tokens = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(api.decode)(params, cache, tokens,
                                         jnp.asarray(5, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize(
    "name", ["qwen1.5-0.5b", "yi-6b", "dbrx-132b", "mamba2-370m",
             "zamba2-1.2b", "whisper-tiny", "internvl2-1b"])
def test_prefill_decode_matches_forward(name):
    """Teacher forcing: forward(tokens[0:n]) logits at position n-1 must
    equal prefill(tokens[0:k]) + decode steps for the rest."""
    cfg = dataclasses.replace(get_config(name).reduced(), remat=False)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    n, k = 16, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, n)), jnp.int32)
    batch = {"tokens": toks}
    vis = 0
    if cfg.family == "vlm":
        batch["vis_embeds"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.n_vis_tokens, cfg.d_model))
        vis = cfg.n_vis_tokens
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(8), (B, 32, cfg.d_model))

    full_logits, _ = api.forward(params, batch)

    pre = {k2: (v[:, :k] if k2 == "tokens" else v) for k2, v in batch.items()}
    if cfg.family == "encdec":
        cache = api.init_cache(B, max_len=n)
        cache = {**cache, "xk": cache["xk"][:, :, :32], "xv": cache["xv"][:, :, :32]}
        logits, cache = api.prefill(params, pre, n)
    else:
        logits, cache = api.prefill(params, pre, n + vis)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, vis + k - 1]),
        rtol=2e-2, atol=2e-2)

    for i in range(k, n):
        logits, cache = api.decode(params, cache, toks[:, i:i + 1],
                                   jnp.asarray(vis + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, vis + i]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{name}: decode step {i} diverges from forward")


def test_structured_pipeline_is_learnable():
    """A couple hundred steps on the structured stream should clearly cut
    the loss below the uniform baseline ln(V)."""
    cfg = get_config("qwen1.5-0.5b").reduced(vocab=64, n_layers=2)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(api, opt))
    pipe = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i, b in enumerate(pipe.batches(120)):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.8 * np.log(cfg.vocab), (losses[0], losses[-1])
