"""Mid-run SDN rerouting: the precompiled route-matrix bank.

Covers the PR 9 acceptance bar: route-state enumeration from an event
schedule (bounded by event boundaries), the numpy ``routes_at(t)`` oracle
vs the compiled in-scan gather over a tick grid with chunk-straddling
events, fleet/campaign parity for all four policies, the bitwise
static-path guarantee (a single-state schedule compiles exactly like
``reroute=False``), and the cross-layer claim — app-aware allocation *with*
rerouting beats app-aware *without* rerouting after a core failure with a
surviving alternate path.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.net import (
    LinkKind,
    RouteSchedule,
    big_switch,
    fat_tree,
    link_failure_schedule,
)
from repro.net.topology import ROUTE_DOWN_THRESHOLD
from repro.streams import (
    FleetRunner,
    compile_fleet,
    compile_sim,
    link_failure_sweep,
    parallelize,
    round_robin,
    simulate,
    trending_topics,
)
from repro.streams.simulator import (
    INTERNAL_RATE,
    _route_states_over,
    metric_index,
)

SECONDS = 40.0
DT = 0.5


def _tt_graph():
    return parallelize(trending_topics(), seed=0)


def _multihop_topo(cap: float = 1.875):
    return fat_tree(up=12.5).set_capacity(LinkKind.INTERNAL, cap)


def _core_links(topo, core: int) -> np.ndarray:
    """All internal links touching one core (rack->core and core->rack)."""
    return np.concatenate([
        topo.rack_to_core_idx[:, core], topo.core_to_rack_idx[core, :]])


def _flows(graph, placement):
    return graph.flow_pairs(placement)


class TestRouteSchedule:
    def test_fail_recover_enumerates_two_states(self):
        g = _tt_graph()
        topo = _multihop_topo()
        failed = _core_links(topo, 0)
        sched = link_failure_schedule(topo, failed, 20.0, 40.0)
        rs = RouteSchedule.from_events(
            topo, _flows(g, round_robin(g, topo.n_machines)), sched)
        # intervals: [0, 20) base, [20, 40) failed, [40, inf) base again —
        # the recovery dedupes back onto state 0
        assert rs.n_intervals == 3
        assert rs.n_states == 2
        np.testing.assert_array_equal(rs.t0, [0.0, 20.0, 40.0])
        np.testing.assert_array_equal(rs.state, [0, 1, 0])
        assert not rs.down[0].any()
        np.testing.assert_array_equal(np.flatnonzero(rs.down[1]),
                                      np.sort(failed))
        # state bound: ≤ 2E + 1 boundaries
        assert rs.n_states <= 2 * len(failed) + 1

    def test_threshold_gates_rerouting(self):
        g = _tt_graph()
        topo = _multihop_topo()
        flows = _flows(g, round_robin(g, topo.n_machines))
        mild = link_failure_schedule(topo, _core_links(topo, 0), 20.0, 40.0,
                                     degrade=ROUTE_DOWN_THRESHOLD + 0.1)
        rs = RouteSchedule.from_events(topo, flows, mild)
        # a brown-out above the threshold never changes the route set
        assert rs.n_states == 1
        deep = link_failure_schedule(topo, _core_links(topo, 0), 20.0, 40.0,
                                     degrade=ROUTE_DOWN_THRESHOLD - 0.1)
        assert RouteSchedule.from_events(topo, flows, deep).n_states == 2

    def test_core_failure_repicks_surviving_core(self):
        topo = fat_tree()  # 2 cores
        # machine 0 (rack 0) -> machine 7 (rack 3): ECMP picks core 1
        flows = [(0, 7), (0, 6)]
        base = topo.routing_matrix(flows)
        down = np.zeros(topo.n_links, bool)
        down[_core_links(topo, 0)] = True
        rs = RouteSchedule.from_events(
            topo, flows, link_failure_schedule(topo, _core_links(topo, 0),
                                               20.0, 40.0))
        failed_R = rs.routes[1]
        for f, (s, d) in enumerate(flows):
            path = np.flatnonzero(failed_R[f])
            # rerouted path avoids every down link and keeps endpoints
            assert not down[path].any()
            assert int(topo.uplink_idx[s]) in path
            assert int(topo.downlink_idx[d]) in path
        # flow (0, 6) used core 0 (0+6 mod 2) — it must move to core 1
        assert (np.flatnonzero(failed_R[1]) != np.flatnonzero(base[1])).any()
        # flow (0, 7) already used core 1 — minimally disruptive: unchanged
        np.testing.assert_array_equal(failed_R[0], base[0])

    def test_dead_route_retained_when_no_alternate(self):
        topo = fat_tree()
        flows = [(0, 7)]
        base = topo.routing_matrix(flows).astype(np.float32)
        up0 = int(topo.uplink_idx[0])
        rs = RouteSchedule.from_events(
            topo, flows, link_failure_schedule(topo, [up0], 20.0, 40.0))
        # uplinks have no alternates: the flow keeps its dead base route
        assert rs.n_states == 2
        assert rs.down[1][up0]
        np.testing.assert_array_equal(rs.routes[1], base)

    def test_state_at_matches_interval_semantics(self):
        g = _tt_graph()
        topo = _multihop_topo()
        sched = link_failure_schedule(topo, _core_links(topo, 0), 20.0, 40.0)
        rs = RouteSchedule.from_events(
            topo, _flows(g, round_robin(g, topo.n_machines)), sched)
        # half-open [t0, t1): failed exactly at t_fail, back at t_recover
        assert rs.state_at(19.9) == 0
        assert rs.state_at(20.0) == 1
        assert rs.state_at(39.9) == 1
        assert rs.state_at(40.0) == 0
        np.testing.assert_array_equal(rs.routes_at(25.0), rs.routes[1])


class TestCompiledParity:
    def _reroute_sim(self, t_fail=13.3, t_recover=27.7):
        g = _tt_graph()
        topo = _multihop_topo()
        sched = link_failure_schedule(topo, _core_links(topo, 0),
                                      t_fail, t_recover)
        pl = round_robin(g, topo.n_machines)
        rs = RouteSchedule.from_events(topo, _flows(g, pl), sched)
        sim = compile_sim(g, topo, pl, schedule=sched, reroute=rs)
        return sim, rs

    def test_compiled_gather_matches_numpy_oracle(self):
        # event boundaries deliberately off the tick grid *and* straddling
        # the campaign chunk boundaries used below
        sim, rs = self._reroute_sim()
        assert sim.is_rerouting
        ts = np.arange(int(SECONDS / DT), dtype=np.float32) * DT
        states = np.asarray(_route_states_over(sim, jnp.asarray(ts)))
        bank = np.asarray(sim.route_bank)
        for k, t in enumerate(ts):
            np.testing.assert_array_equal(bank[states[k]], rs.routes_at(t))

    def test_single_state_schedule_is_bitwise_static(self):
        # events above the threshold: reroute=True collapses to S_r = 0 and
        # the run is bitwise the reroute=False path
        g = _tt_graph()
        topo = _multihop_topo()
        sched = link_failure_schedule(topo, _core_links(topo, 0), 10.0, 30.0,
                                      degrade=0.8)
        pl = round_robin(g, topo.n_machines)
        base = compile_sim(g, topo, pl, schedule=sched)
        rer = compile_sim(g, topo, pl, schedule=sched, reroute=True)
        assert not rer.is_rerouting
        for policy in ("tcp", "appaware"):
            a = simulate(base, policy, seconds=SECONDS, dt=DT)
            b = simulate(rer, policy, seconds=SECONDS, dt=DT)
            np.testing.assert_array_equal(a.sink_mb, b.sink_mb)
            np.testing.assert_array_equal(a.link_load, b.link_load)
            np.testing.assert_array_equal(a.metrics, b.metrics)


class TestFleetParity:
    @pytest.fixture(scope="class")
    def mixed_sims(self):
        # reroute scenarios + a static scenario + an in-run capacity-only
        # failure: exercises mixed-bucket padding of the route fields
        scens = link_failure_sweep(n=2, seed=3, reroute=True)
        scens += link_failure_sweep(n=1, seed=3, in_run=True)
        g = _tt_graph()
        topo = big_switch(8, 1.25)
        sims = compile_fleet(scens) + [compile_sim(g, topo, round_robin(g, 8))]
        assert any(s.is_rerouting for s in sims)
        assert any(not s.is_rerouting for s in sims)
        return sims

    @pytest.mark.parametrize("policy", ["tcp", "appaware", "appfair", "fixed"])
    def test_fleet_matches_standalone(self, mixed_sims, policy):
        runner = FleetRunner()
        xf = None
        if policy == "fixed":
            xf = [np.full(int(np.asarray(s.has_links).shape[0]), 0.05,
                          np.float32) for s in mixed_sims]
        res = runner.run(mixed_sims, policy, seconds=SECONDS, dt=DT,
                         x_fixed=xf, shard=False)
        for b, sim in enumerate(mixed_sims):
            ref = simulate(sim, policy, seconds=SECONDS, dt=DT,
                           x_fixed=None if xf is None else xf[b])
            # fleet padding re-associates contractions (same ≤ 1e-5 bound
            # the padding-neutrality suite pins); bitwise contracts live in
            # the campaign streamed-vs-materialized comparison below
            np.testing.assert_allclose(res[b].sink_mb, ref.sink_mb,
                                       atol=1e-5)
            np.testing.assert_allclose(res[b].metrics, ref.metrics,
                                       rtol=1e-5, atol=1e-5)

    def test_campaign_chunks_straddle_route_events(self, mixed_sims):
        # chunk_rows=2 forces multiple chunks per bucket; the route bank
        # must ride into every chunk identically — streamed metrics are
        # bitwise the materialized fleet run's
        runner = FleetRunner()
        camp = runner.run_campaign(mixed_sims, "appaware", seconds=SECONDS,
                                   dt=DT, chunk_rows=2, shard=False)
        res = runner.run(mixed_sims, "appaware", seconds=SECONDS, dt=DT,
                         shard=False)
        for b in range(len(mixed_sims)):
            np.testing.assert_array_equal(camp.metrics[b], res[b].metrics)


class TestRerouteRecovery:
    def test_appaware_reroute_beats_no_reroute_post_failure(self):
        """The headline claim: with a surviving alternate core path, SDN
        rerouting recovers post-failure throughput that capacity-aware
        allocation alone cannot (it can only starve the dead routes)."""
        g = _tt_graph()
        topo = _multihop_topo()
        # 4-link mid-run failure: every rack->core-0 link dies at t = 60 s
        # (no recovery), so all cross-rack flows ECMP-mapped to core 0
        # lose their path unless rerouted through core 1
        failed = topo.rack_to_core_idx[:, 0]
        assert len(failed) == 4
        sched = link_failure_schedule(topo, failed, 60.0)
        pl = round_robin(g, topo.n_machines)
        base = compile_sim(g, topo, pl, schedule=sched)
        rer = compile_sim(g, topo, pl, schedule=sched, reroute=True)
        assert rer.is_rerouting

        def post_failure_tput(sim):
            r = simulate(sim, "appaware", seconds=120.0, dt=DT,
                         t_event=60.0)
            post = r.sink_mb[int(60.0 / DT):]
            return float(post.sum() / (len(post) * DT))

        with_rr = post_failure_tput(rer)
        without = post_failure_tput(base)
        assert with_rr >= 1.1 * without, (
            f"reroute {with_rr:.3f} MB/s vs no-reroute {without:.3f} MB/s")


def test_internal_rate_unaffected_by_reroute():
    # internal (same-machine) flows never enter the routing matrix; a
    # reroute state must leave their rate pinned at INTERNAL_RATE
    g = _tt_graph()
    topo = _multihop_topo()
    pl = np.zeros(g.n_instances, dtype=np.int64)  # everything co-located
    sched = link_failure_schedule(topo, _core_links(topo, 0), 10.0, 30.0)
    sim = compile_sim(g, topo, pl, schedule=sched, reroute=True)
    # all flows internal -> no routed links at all -> nothing to reroute
    assert not np.asarray(sim.has_links).any()
    assert not sim.is_rerouting or np.asarray(sim.route_bank).sum() == 0
    r = simulate(sim, "tcp", seconds=10.0, dt=DT)
    assert np.isfinite(r.sink_mb).all()
    assert INTERNAL_RATE > 0  # imported constant still the internal pin


def test_metric_index_stable():
    # consumers (campaign CSVs, the ULP pin in test_multidevice) address
    # metrics by name; keep the total_sink_mb column where they expect it
    assert metric_index("total_sink_mb") == 6
