"""Tests for the cross-layer collective-flow scheduler (paper technique
applied to the training fabric) and dry-run artifact validation."""
import json
import pathlib
import textwrap

import pytest

from repro.core.scheduler import CollectiveFlow, extract_flows, plan_schedule

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


class TestExtractFlows:
    HLO = textwrap.dedent("""\
      %ar = f32[16,512]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
      %ag = bf16[64,1024]{1,0} all-gather(%y), replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
    """)

    def test_axis_attribution(self):
        flows = extract_flows(self.HLO, {"data": 16, "model": 16})
        assert len(flows) == 2
        # contiguous groups ride the minor ("model") axis; strided the major
        assert flows[0].axis == "model"
        assert flows[1].axis == "data"
        assert flows[0].bytes == 16 * 512 * 4
        assert flows[1].bytes == 64 * 1024 * 2 / 16  # all-gather operand

    def test_plan_schedule_properties(self):
        flows = [
            CollectiveFlow("g1", "all-reduce", 1e9, "data"),
            CollectiveFlow("g2", "all-reduce", 2e9, "data"),
            CollectiveFlow("a1", "all-gather", 5e8, "model"),
            CollectiveFlow("dcn", "all-reduce", 1e8, "pod"),
        ]
        sched = plan_schedule(flows, {"pod": 2, "data": 16, "model": 16},
                              step_compute_s=0.1)
        assert len(sched.order) == 4
        assert sched.rates.shape == (4,)
        assert (sched.rates >= 0).all()
        assert sched.est_total_comm_s > 0
        # per-axis allocation is capacity-feasible
        for axis, bw in (("data", 50e9), ("model", 50e9), ("pod", 6.25e9)):
            tot = sum(r for r, f in zip(sched.rates, flows) if f.axis == axis)
            assert tot <= bw * 1.001
        # bigger flows on the same axis get proportionally more bandwidth
        r = {f.name: r for f, r in zip(flows, sched.rates)}
        assert r["g2"] > r["g1"]

    def test_empty(self):
        sched = plan_schedule([], {"data": 4}, 0.1)
        assert sched.order == []


# Skip audit (PR 4): all four tests below validate artifacts that only the
# dry-run driver produces, and producing them is NOT tier-1 material — it
# fakes 512 host devices and XLA-compiles every (arch × shape × mesh) cell
# of the production meshes, minutes per cell on a CPU runner. The blocker
# is therefore real (no artifacts in a fresh checkout), not stale; the
# reason names the exact regeneration command so the skip is actionable.
@pytest.mark.skipif(
    not RESULTS.exists() or not list(RESULTS.glob("*.json")),
    reason="results/dryrun/*.json absent — generate with "
           "`PYTHONPATH=src python -m repro.launch.dryrun --arch all "
           "--shape all` (fakes 512 host devices; XLA-compiles every "
           "arch×shape×mesh cell, far too slow for tier-1)")
class TestDryrunArtifacts:
    def _records(self):
        return [json.loads(f.read_text()) for f in RESULTS.glob("*.json")]

    def test_all_cells_compiled(self):
        recs = self._records()
        bad = [f"{r['arch']}/{r['shape']}/{r['mesh']}: {r.get('error')}"
               for r in recs if not r.get("ok")]
        assert not bad, bad

    def test_memory_fits_hbm(self):
        # v5e: 16 GB HBM per chip
        for r in self._records():
            if not r.get("ok"):
                continue
            peak = r["memory"].get("peak_memory_in_bytes")
            if peak:
                assert peak <= 16e9, (
                    f"{r['arch']}/{r['shape']}/{r['mesh']} "
                    f"peak {peak / 1e9:.1f} GB > 16 GB")

    @staticmethod
    def _coll_count(r):
        # probe-derived `collectives` carries per-kind bytes; the op count
        # lives in the raw (rolled-artifact) stats
        return (r.get("collectives_raw") or r.get("collectives", {})).get(
            "count", 0)

    def test_flops_positive_and_collectives_present(self):
        for r in self._records():
            if not r.get("ok"):
                continue
            assert r["flops"] > 0
            assert self._coll_count(r) > 0, (
                f"{r['arch']}/{r['shape']}/{r['mesh']}: SPMD program "
                "contains no collectives — sharding is broken")

    def test_multipod_pod_axis_shards(self):
        """Multi-pod train cells must communicate across the pod axis
        (batch is sharded over it): total collective traffic should not be
        LOWER than single-pod for the same cell."""
        recs = {(r["arch"], r["shape"], r["mesh"]): r
                for r in self._records() if r.get("ok")}
        pairs = 0
        for (arch, shape, mesh), r in recs.items():
            if mesh != "pod_16x16" or r["kind"] != "train":
                continue
            r2 = recs.get((arch, shape, "multipod_2x16x16"))
            if r2 is None:
                continue
            pairs += 1
            assert self._coll_count(r2) >= 1
        assert pairs >= 1
