"""Fault-tolerance & distributed-optimization substrate tests:
checkpoint/restart, straggler handling, elastic resharding, gradient
compression, serving engine."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLM
from repro.models.registry import get_config, get_model
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import Checkpointer
from repro.train.compression import (
    compressed_bytes_ratio,
    dequantize_int8,
    ef_init,
    int8_roundtrip,
    quantize_int8,
    topk_ef_transform,
)
from repro.train.driver import DriverConfig, TrainDriver
from repro.train.optim import AdamW, warmup_cosine


def _tiny():
    cfg = get_config("qwen1.5-0.5b").reduced(vocab=64, n_layers=2)
    return get_model(cfg)


class TestCheckpoint:
    def test_roundtrip_and_integrity(self, tmp_path):
        api = _tiny()
        params = api.init(jax.random.PRNGKey(0))
        ck = Checkpointer(tmp_path, keep=2)
        ck.save(7, {"params": params})
        restored, step = ck.restore({"params": params})
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_and_retention(self, tmp_path):
        api = _tiny()
        params = api.init(jax.random.PRNGKey(0))
        ck = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"params": params})
        ckpts = sorted(p.name for p in pathlib.Path(tmp_path).glob("ckpt_*"))
        assert ckpts == ["ckpt_00000003", "ckpt_00000004"]
        assert not list(pathlib.Path(tmp_path).glob("*.tmp"))

    def test_corruption_detected(self, tmp_path):
        api = _tiny()
        params = api.init(jax.random.PRNGKey(0))
        ck = Checkpointer(tmp_path)
        ck.save(1, {"params": params})
        f = next(pathlib.Path(tmp_path).glob("ckpt_*/arrays.npz"))
        data = bytearray(f.read_bytes())
        data[len(data) // 2] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(Exception):
            ck.restore({"params": params})

    def test_async_save(self, tmp_path):
        api = _tiny()
        params = api.init(jax.random.PRNGKey(0))
        ck = Checkpointer(tmp_path)
        ck.save_async(5, {"params": params})
        ck.wait()
        assert ck.latest_step() == 5


class TestDriver:
    def test_failure_restart_resumes_stream(self, tmp_path):
        api = _tiny()
        pipe = SyntheticLM(vocab=api.cfg.vocab, seq_len=32, global_batch=4)
        dcfg = DriverConfig(steps=25, ckpt_every=10,
                            ckpt_dir=str(tmp_path))
        drv = TrainDriver(api, AdamW(lr=1e-3), pipe, dcfg,
                          failure_at={17})
        params, _, step = drv.run()
        assert step == 25
        kinds = [e for _, e in drv.events]
        assert any("failure" in k for k in kinds)
        assert any("restart-from-ckpt" in k for k in kinds)
        # deterministic: a clean run reaches the same loss trajectory
        dcfg2 = DriverConfig(steps=25, ckpt_every=10,
                             ckpt_dir=str(tmp_path) + "_clean")
        drv2 = TrainDriver(api, AdamW(lr=1e-3), pipe, dcfg2)
        params2, _, _ = drv2.run()
        final = {m["step"]: m["loss"] for m in drv.metrics}
        final2 = {m["step"]: m["loss"] for m in drv2.metrics}
        assert final[24] == pytest.approx(final2[24], rel=1e-4)

    def test_straggler_replay(self, tmp_path):
        api = _tiny()
        pipe = SyntheticLM(vocab=api.cfg.vocab, seq_len=32, global_batch=4)
        dcfg = DriverConfig(steps=6, ckpt_every=100, ckpt_dir=str(tmp_path),
                            deadline_s=0.2)
        drv = TrainDriver(api, AdamW(lr=1e-3), pipe, dcfg,
                          straggle_at={3: 0.5})
        _, _, step = drv.run()
        assert step == 6
        assert any("straggler" in e for _, e in drv.events)

    def test_elastic_reshard(self, tmp_path):
        api = _tiny()
        pipe = SyntheticLM(vocab=api.cfg.vocab, seq_len=32, global_batch=4)
        dcfg = DriverConfig(steps=2, ckpt_every=100, ckpt_dir=str(tmp_path))
        drv = TrainDriver(api, AdamW(lr=1e-3), pipe, dcfg)
        params, opt_state, _ = drv.run()
        # reshard onto the (single-device) mesh: exercises the device_put path
        from repro.launch.mesh import make_local_mesh
        from repro.launch.shardings import param_shardings
        mesh = make_local_mesh()
        p_sh = param_shardings(mesh, api)
        from repro.train.optim import AdamState
        o_sh = AdamState(step=None, m=p_sh, v=p_sh)
        # build sharding tree with step replicated
        from jax.sharding import NamedSharding, PartitionSpec as P
        o_sh = AdamState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
        p2, o2 = drv.reshard_to(params, opt_state, p_sh, o_sh)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x).max()
        assert float(err) <= float(s) * 0.5 + 1e-6
        assert q.dtype == jnp.int8

    def test_topk_ef_conserves_mass(self):
        g = {"a": jnp.arange(-8.0, 8.0), "b": jnp.ones((4, 4))}
        st = ef_init(g)
        kept, st2 = topk_ef_transform(g, st, fraction=0.25)
        # kept + error == original (+ previous error 0)
        for k in g:
            np.testing.assert_allclose(
                np.asarray(kept[k] + st2.error[k]), np.asarray(g[k]),
                rtol=1e-6)

    def test_ef_training_still_converges(self):
        api = _tiny()
        pipe = SyntheticLM(vocab=api.cfg.vocab, seq_len=32, global_batch=8)
        opt = AdamW(lr=3e-3)
        params = api.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        ef = ef_init(params)

        from repro.train.step import make_loss_fn
        loss_fn = make_loss_fn(api)
        grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

        @jax.jit
        def apply(params, opt_state, ef, batch):
            (_, metrics), grads = grad_fn(params, batch)
            kept, ef = topk_ef_transform(grads, ef, fraction=0.1)
            kept = int8_roundtrip(kept)
            updates, opt_state, _ = opt.update(kept, opt_state, params)
            from repro.train.optim import apply_updates
            return apply_updates(params, updates), opt_state, ef, metrics

        losses = []
        for i, b in enumerate(pipe.batches(150)):
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt_state, ef, m = apply(params, opt_state, ef, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.9 * np.log(api.cfg.vocab)

    def test_wire_ratio(self):
        assert compressed_bytes_ratio(0.01) < 0.05  # >20x reduction


class TestServeEngine:
    def test_batched_greedy_decode(self):
        api = _tiny()
        params = api.init(jax.random.PRNGKey(0))
        eng = ServeEngine(api, max_len=64, batch_slots=2)
        eng.load(params)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, api.cfg.vocab, 8,
                                            dtype=np.int32),
                        max_new_tokens=5) for _ in range(5)]
        eng.run(reqs)
        assert all(r.done for r in reqs)
        assert all(len(r.out) == 5 for r in reqs)

    def test_decode_matches_prefill_teacher_forcing(self):
        api = _tiny()
        params = api.init(jax.random.PRNGKey(1))
        eng = ServeEngine(api, max_len=64, batch_slots=1)
        eng.load(params)
        prompt = np.arange(8, dtype=np.int32)
        r = Request(prompt=prompt, max_new_tokens=4)
        eng.run([r])
        # re-running the same request is deterministic
        r2 = Request(prompt=prompt, max_new_tokens=4)
        eng.run([r2])
        assert r.out == r2.out


def test_schedule_warmup_cosine():
    lr = warmup_cosine(1.0, warmup=10, total=110, floor=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(110))) == pytest.approx(0.1)
