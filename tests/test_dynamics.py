"""In-run network dynamics: time-varying link capacities threaded through
the simulator, policies, fleet engine, and metrics.

Covers the PR 3 acceptance bar: a constant `LinkSchedule` reproduces the
static path (≤ 1e-5 — in fact bitwise: zero-amplitude sinusoids and
never-active events multiply by exactly 1.0), per-tick conservation holds
through a failure + recovery schedule, and the cross-layer claim — the
app-aware allocator recovers from a mid-run link failure with higher
post-event throughput than TCP."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.net import (
    LinkSchedule,
    big_switch,
    diurnal_schedule,
    link_failure_schedule,
)
from repro.streams import (
    Edge,
    Grouping,
    Operator,
    StreamApp,
    compile_sim,
    link_failure_sweep,
    parallelize,
    round_robin,
    simulate,
    time_varying_sweep,
    trending_topics,
    trucking_iot,
)
from repro.streams.simulator import INTERNAL_RATE, _caps_over, _tick

DT = 0.5


def _seed_sim(mk=trending_topics, cap=1.25, schedule=None):
    g = parallelize(mk(), seed=0)
    topo = big_switch(8, cap)
    return compile_sim(g, topo, round_robin(g, 8), schedule=schedule), topo


class TestScheduleEvaluation:
    def test_constant_schedule_is_identity(self):
        topo = big_switch(4, 2.0)
        sched = LinkSchedule.constant(topo.n_links)
        ts = np.linspace(0.0, 600.0, 50)
        caps = sched.caps_at(topo.capacities, ts)
        np.testing.assert_array_equal(
            caps, np.broadcast_to(topo.capacities, caps.shape))

    def test_jax_matches_numpy_reference(self):
        g = parallelize(trending_topics(), seed=0)
        topo = big_switch(8, 1.25)
        sched = (
            link_failure_schedule(topo, [1, 3], 20.0, 40.0, degrade=0.25)
            .with_diurnal(120.0, 0.3, phase=0.7)
            .with_event([2], 10.0, scale=0.5)  # permanent brown-out
        )
        sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        ts = np.arange(120, dtype=np.float32) * DT
        caps_jax = np.asarray(_caps_over(sim, jnp.asarray(ts)))
        caps_np = sched.caps_at(topo.capacities, ts)
        np.testing.assert_allclose(caps_jax, caps_np, rtol=1e-5, atol=1e-6)

    def test_events_compose_multiplicatively(self):
        topo = big_switch(2, 4.0)
        sched = (LinkSchedule.empty(topo.n_links)
                 .with_event([0], 0.0, 10.0, scale=0.5)
                 .with_event([0], 5.0, 10.0, scale=0.5))
        caps = sched.caps_at(topo.capacities, np.array([2.0, 7.0, 12.0]))
        np.testing.assert_allclose(caps[:, 0], [2.0, 1.0, 4.0], rtol=1e-6)

    # times whose float32 rounding moves them: the f64-vs-f32 comparison
    # mismatch at t == t0 / t == t1 is exactly what the boundary fix pinned
    _BOUNDARY_TIMES = [(0.1, 0.3), (1.0 / 3.0, 2.0 / 3.0), (20.0, 40.0)]

    @pytest.mark.parametrize("t0,t1", _BOUNDARY_TIMES)
    def test_boundary_time_parity(self, t0, t1):
        """Half-open [t0, t1) exactly at the boundaries, numpy == compiled.

        ``caps_at`` used to upcast the query time to float64 while the
        stored event times stay float32: for any t0 that f32 rounds
        *upward* (0.1, 1/3, …) the f64 query t == t0 landed below the
        stored boundary, so the oracle said inactive at the event's own
        start time while the compiled f32 path said active (and the
        mirror image at t1). Both sides now decide activity at f32.
        """
        g = parallelize(trending_topics(), seed=0)
        topo = big_switch(8, 1.25)
        sched = LinkSchedule.empty(topo.n_links).with_event(
            [2], t0, t1, scale=0.25)
        sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        eps = 1e-3
        ts = np.array([t0 - eps, t0, t1, t1 + eps], np.float32)
        caps_np = sched.caps_at(topo.capacities, ts)
        caps_jax = np.asarray(_caps_over(sim, jnp.asarray(ts)))
        np.testing.assert_array_equal(caps_jax, caps_np.astype(np.float32))
        # the half-open contract itself: active at t0, inactive at t1
        assert caps_np[1, 2] == pytest.approx(1.25 * 0.25)
        assert caps_np[0, 2] == pytest.approx(1.25)
        assert caps_np[2, 2] == pytest.approx(1.25)

    def test_overlap_composition_parity_at_boundaries(self):
        """Overlapping same-link events compose multiplicatively on both
        sides, including exactly at each event's boundary ticks."""
        g = parallelize(trending_topics(), seed=0)
        topo = big_switch(8, 2.0)
        sched = (LinkSchedule.empty(topo.n_links)
                 .with_event([4], 0.1, 0.7, scale=0.5)
                 .with_event([4], 0.3, 0.9, scale=0.5))
        sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        ts = np.array([0.0, 0.1, 0.2, 0.3, 0.5, 0.7, 0.8, 0.9], np.float32)
        caps_np = sched.caps_at(topo.capacities, ts)
        caps_jax = np.asarray(_caps_over(sim, jnp.asarray(ts)))
        np.testing.assert_array_equal(caps_jax, caps_np.astype(np.float32))
        np.testing.assert_allclose(
            caps_np[:, 4], [2.0, 1.0, 1.0, 0.5, 0.5, 1.0, 1.0, 2.0],
            rtol=1e-6)

    def test_schedule_link_count_mismatch_rejected(self):
        g = parallelize(trending_topics(), seed=0)
        with pytest.raises(ValueError, match="links"):
            compile_sim(g, big_switch(8, 1.25), round_robin(g, 8),
                        schedule=LinkSchedule.constant(3))


class TestConstantScheduleParity:
    """Acceptance: a constant LinkSchedule reproduces current static-caps
    results (≤ 1e-5 on sink_mb / latency for seed scenarios)."""

    @pytest.mark.parametrize("policy", ["tcp", "appaware"])
    @pytest.mark.parametrize("mk", [trending_topics, trucking_iot])
    def test_parity(self, mk, policy):
        sim, topo = _seed_sim(mk)
        simc, _ = _seed_sim(mk, schedule=LinkSchedule.constant(topo.n_links))
        ref = simulate(sim, policy, seconds=60.0, dt=DT)
        got = simulate(simc, policy, seconds=60.0, dt=DT)
        np.testing.assert_allclose(got.sink_mb, ref.sink_mb, atol=1e-5)
        np.testing.assert_allclose(got.latency, ref.latency,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got.link_load, ref.link_load, atol=1e-5)
        # the constant schedule went down the dynamic path: caps trajectory
        # is reported, and equals the static capacities at every tick
        assert got.caps_t is not None and ref.caps_t is None
        np.testing.assert_array_equal(
            got.caps_t, np.broadcast_to(ref.caps, got.caps_t.shape))


class TestEnforcement:
    def test_failed_link_moves_no_bytes(self):
        sim, topo = _seed_sim(schedule=link_failure_schedule(
            big_switch(8, 1.25), [0, 1], 15.0, 25.0, degrade=0.0))
        r = simulate(sim, "tcp", seconds=40.0, dt=DT)
        i0, i1 = int(15.0 / DT), int(25.0 / DT)
        assert np.abs(r.link_load[i0:i1, :2]).max() == 0.0
        # and the links carry traffic again after recovery
        assert r.link_load[i1:, 0].max() > 0.0

    @pytest.mark.parametrize("policy", ["tcp", "appaware"])
    def test_load_respects_scheduled_caps_every_tick(self, policy):
        topo = big_switch(8, 1.25)
        sched = (link_failure_schedule(topo, [2, 3], 10.0, 20.0, degrade=0.2)
                 .with_diurnal(40.0, 0.3))
        sim, _ = _seed_sim(schedule=sched)
        r = simulate(sim, policy, seconds=40.0, dt=DT)
        assert r.caps_t is not None
        assert np.all(r.link_load <= r.caps_t * (1 + 1e-3) + 1e-6)


class TestConservationUnderSchedule:
    """Total MB conserved across transfer/consume/emit at *every tick* of a
    failure + recovery schedule (satellite task)."""

    def test_per_tick_conservation_through_failure(self):
        app = StreamApp(
            "cons",
            [Operator("src", 1, gen_rate=0.8, proc_rate=100.0),
             Operator("mid", 2, proc_rate=100.0, selectivity=1.0),
             Operator("sink", 1, proc_rate=100.0, selectivity=0.0)],
            [Edge("src", "mid", Grouping.SHUFFLE),
             Edge("mid", "sink", Grouping.GLOBAL)],
        )
        g = parallelize(app, seed=0)
        topo = big_switch(4, 5.0)
        sched = link_failure_schedule(topo, list(range(topo.n_links // 2)),
                                      10.0, 20.0, degrade=0.0)
        sim = compile_sim(g, topo, round_robin(g, 4), schedule=sched)
        F = g.n_flows
        qcap = 8.0
        x = jnp.where(sim.has_links, 5.0, INTERNAL_RATE)
        Qs = Qr = jnp.zeros((F,), jnp.float32)
        delivered = 0.0
        base = np.asarray(sim.caps)
        T = 80  # 40 s: failure at 10 s, recovery at 20 s
        for t in range(T):
            caps_t = jnp.asarray(sched.caps_at(base, t * DT), jnp.float32)
            Qs, Qr, transfer, _, (sink, _, _, load) = _tick(
                sim, Qs, Qr, x, DT, qcap, caps_t=caps_t)
            delivered += float(sink)
            # the network never exceeds the *scheduled* capacity
            assert np.all(np.asarray(load) <= np.asarray(caps_t) * (1 + 1e-3))
            # nothing minted, nothing lost — at every tick
            generated = 0.8 * DT * (t + 1)
            total = delivered + float(jnp.sum(Qs) + jnp.sum(Qr))
            np.testing.assert_allclose(total, generated, rtol=1e-3)
        # the outage actually bit: something was still queued at the end
        assert delivered < 0.8 * DT * T


class TestMidRunFailureRegression:
    """Acceptance: appaware recovers from a mid-run link failure with
    higher post-event throughput than tcp — the paper's cross-layer claim
    exercised in its transient regime."""

    T_FAIL, T_REC = 50.0, 70.0

    def _post_tput(self, r, t_event):
        i = int(t_event / r.dt)
        return float(r.sink_mb[i:].mean() / r.dt * r.tuples_per_mb)

    @pytest.mark.parametrize("mk", [trending_topics, trucking_iot])
    def test_appaware_beats_tcp_after_failure(self, mk):
        topo = big_switch(8, 1.25)
        sched = link_failure_schedule(topo, [0, 1, 2, 3], self.T_FAIL,
                                      self.T_REC, degrade=0.1)
        g = parallelize(mk(), seed=0)
        sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        tcp = simulate(sim, "tcp", seconds=120.0, dt=DT)
        aa = simulate(sim, "appaware", seconds=120.0, dt=DT)
        assert (self._post_tput(aa, self.T_FAIL)
                > self._post_tput(tcp, self.T_FAIL) * 1.10)

    def test_transient_metrics(self):
        topo = big_switch(8, 1.25)
        sched = link_failure_schedule(topo, [0, 1, 2, 3], self.T_FAIL,
                                      self.T_REC, degrade=0.1)
        g = parallelize(trending_topics(), seed=0)
        sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        r = simulate(sim, "tcp", seconds=120.0, dt=DT)
        assert r.dip_depth(self.T_FAIL) > 0.3        # the failure bites
        assert np.isfinite(r.recovery_time_s(self.T_FAIL))
        # a static run of the same workload shows no comparable dip
        static, _ = _seed_sim()
        rs = simulate(static, "tcp", seconds=120.0, dt=DT)
        assert rs.dip_depth(self.T_FAIL) < r.dip_depth(self.T_FAIL)


class TestTransientCalibration:
    """Golden-trace regression pinning the transient metrics of the PR 3
    mid-run 4-link-failure scenario (the paper's Fig. 5/12 regime): solver
    or policy changes that silently shift dip depth or settling time now
    fail here instead of drifting unnoticed. The simulator is
    deterministic, so the bands only absorb float/jax-version jitter
    (recovery time is additionally quantized by the 5 s smoothing window).

    Goldens measured with the fused fixed-trip max-min solver (PR 4) at
    seconds=120, dt=0.5 — reproduce with:
        PYTHONPATH=src:tests python -c "from test_dynamics import \
            TestTransientCalibration as T; T().print_goldens()"
    """

    T_FAIL, T_REC = 50.0, 70.0
    DIP_BAND = 0.05          # absolute band on the fractional dip
    REC_BAND_S = 3.0         # band on settling time (6 ticks)

    # (workload, policy) -> (dip_depth, recovery_time_s)
    GOLDEN = {
        ("trending_topics", "tcp"): (0.899, 23.0),
        ("trending_topics", "appaware"): (0.988, 26.5),
        ("trucking_iot", "tcp"): (0.898, 23.0),
        ("trucking_iot", "appaware"): (0.902, 25.5),
    }

    def _run(self, mk, policy):
        topo = big_switch(8, 1.25)
        sched = link_failure_schedule(topo, [0, 1, 2, 3], self.T_FAIL,
                                      self.T_REC, degrade=0.1)
        g = parallelize(mk(), seed=0)
        sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        r = simulate(sim, policy, seconds=120.0, dt=DT)
        return r.dip_depth(self.T_FAIL), r.recovery_time_s(self.T_FAIL)

    def print_goldens(self):  # regeneration helper, not collected
        for mk in (trending_topics, trucking_iot):
            for policy in ("tcp", "appaware"):
                dip, rec = self._run(mk, policy)
                print(f'("{mk.__name__}", "{policy}"): '
                      f'({dip:.3f}, {rec:.1f}),')

    @pytest.mark.parametrize("policy", ["tcp", "appaware"])
    @pytest.mark.parametrize("mk", [trending_topics, trucking_iot])
    def test_transients_match_golden(self, mk, policy):
        dip, rec = self._run(mk, policy)
        g_dip, g_rec = self.GOLDEN[(mk.__name__, policy)]
        assert abs(dip - g_dip) <= self.DIP_BAND, (
            f"dip_depth {dip:.3f} drifted from golden {g_dip:.3f}")
        assert np.isfinite(rec)
        assert abs(rec - g_rec) <= self.REC_BAND_S, (
            f"recovery_time_s {rec:.1f} drifted from golden {g_rec:.1f}")


class TestInRunScenarioGenerators:
    def test_link_failure_sweep_in_run(self):
        scens = link_failure_sweep(n=2, seed=0, in_run=True)
        assert all(s.schedule is not None for s in scens)
        assert all("failrun" in s.name for s in scens)
        sim = scens[0].compile()
        assert sim.ev_t0.shape[0] > 0
        r = simulate(sim, "tcp", seconds=30.0, dt=DT)
        assert np.isfinite(r.sink_mb).all()

    def test_time_varying_sweep_in_run(self):
        scens = time_varying_sweep(n_phases=2, seed=0, in_run=True)
        assert all(s.schedule is not None for s in scens)
        sim = scens[0].compile()
        assert sim.sin_amp.shape[0] > 0
        r = simulate(sim, "appaware", seconds=30.0, dt=DT)
        assert np.isfinite(r.sink_mb).all()
        # the capacity actually moved during the run
        assert r.caps_t is not None
        assert r.caps_t.std(axis=0).max() > 0.0

    def test_steady_state_forms_unchanged(self):
        # the original phase-sampled / degraded-topology forms remain as
        # parity oracles: no schedules attached
        assert all(s.schedule is None for s in link_failure_sweep(n=2))
        assert all(s.schedule is None for s in time_varying_sweep(n_phases=2))


class TestDiurnalTracksCycle:
    def test_throughput_follows_capacity(self):
        # with a slow large-amplitude cycle, delivered volume in the
        # high-capacity half-period exceeds the low-capacity half-period
        topo = big_switch(8, 1.25)
        sched = diurnal_schedule(topo, period_s=80.0, amplitude=0.6)
        g = parallelize(trending_topics(), seed=0)
        sim = compile_sim(g, topo, round_robin(g, 8), schedule=sched)
        r = simulate(sim, "tcp", seconds=80.0, dt=DT)
        half = int(40.0 / DT)
        high = r.sink_mb[:half].sum()     # sin > 0: caps above base
        low = r.sink_mb[half:].sum()      # sin < 0: caps below base
        assert high > low * 1.05
