"""Tests for the stream-analytics substrate: DAG parallelization, placement,
and the fluid simulator's invariants + the paper's headline claims."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.net import LinkKind, big_switch, fat_tree
from repro.streams import (
    Edge,
    Grouping,
    Operator,
    StreamApp,
    compile_sim,
    linkedin_tags,
    motivation_chain,
    parallelize,
    round_robin,
    simulate,
    trending_topics,
    trucking_iot,
)
from repro.streams.placement import STRATEGIES, traffic_aware


class TestParallelize:
    def test_counts_and_groupings(self):
        g = parallelize(linkedin_tags(), seed=0)
        ops = {o.name: o for o in g.app.operators}
        assert g.n_instances == sum(o.parallelism for o in g.app.operators)
        # GLOBAL grouping: count->topk flows all end at the same instance
        topk_flows = [
            f for f in range(g.n_flows)
            if g.inst_names[g.dst_of_flow[f]].startswith("topk")
        ]
        assert len(set(g.dst_of_flow[f] for f in topk_flows)) == 1
        assert len(topk_flows) == ops["count"].parallelism

    def test_output_conservation(self):
        # per-instance outgoing fractions sum to the sum of edge weights (≤1)
        g = parallelize(trending_topics(), seed=0)
        sums = g.w_out.sum(axis=1)
        for i in range(g.n_instances):
            op = g.app.operators[g.op_of_inst[i]]
            expected = sum(
                e.weight for e in g.app.edges if e.src == op.name
            )
            assert sums[i] == pytest.approx(expected, rel=1e-6)

    def test_all_grouping_broadcasts(self):
        app = StreamApp(
            "b", [Operator("s", 1, gen_rate=1.0), Operator("d", 3, proc_rate=10.0)],
            [Edge("s", "d", Grouping.ALL)],
        )
        g = parallelize(app)
        assert g.n_flows == 3
        assert g.w_out.sum() == pytest.approx(3.0)  # duplicated to every dst


class TestPlacement:
    @pytest.mark.parametrize("name", list(STRATEGIES))
    def test_valid(self, name):
        g = parallelize(trending_topics(), seed=0)
        kw = {"seed": 1} if name == "random" else {}
        m = STRATEGIES[name](g, 8, **kw) if name != "random" else STRATEGIES[name](g, 8, 1)
        assert m.shape == (g.n_instances,)
        assert m.min() >= 0 and m.max() < 8

    def test_traffic_aware_colocates_heavy_edges(self):
        g = parallelize(trucking_iot(), seed=0)
        m = traffic_aware(g, 8)
        vols = np.zeros(g.n_flows)
        # heaviest flow endpoints should share a machine more often than not
        from repro.streams.placement import _steady_state_flow_volume
        vols = _steady_state_flow_volume(g)
        heavy = int(np.argmax(vols))
        s, d = g.src_of_flow[heavy], g.dst_of_flow[heavy]
        assert m[s] == m[d]

    @given(app_seed=st.integers(min_value=0, max_value=2**31 - 1),
           n_machines=st.integers(min_value=2, max_value=12),
           slack=st.integers(min_value=0, max_value=3))
    @settings(max_examples=30, deadline=None)
    def test_traffic_aware_respects_cap(self, app_seed, n_machines, slack):
        # every feasible cap binds on every machine — the first-endpoint
        # and leftover placements used to fall back to a bare argmin(load)
        # that silently exceeded a user-supplied cap_per_machine
        from repro.streams.scenarios import random_app

        g = parallelize(random_app(app_seed), seed=app_seed)
        cap = -(-g.n_instances // n_machines) + slack
        m = traffic_aware(g, n_machines, cap_per_machine=cap)
        assert m.min() >= 0 and m.max() < n_machines
        counts = np.bincount(m, minlength=n_machines)
        assert counts.max() <= cap, (counts, cap)

    def test_traffic_aware_infeasible_cap_raises(self):
        g = parallelize(trending_topics(), seed=0)
        with pytest.raises(ValueError, match="cap_per_machine"):
            traffic_aware(g, 4, cap_per_machine=max(
                1, (g.n_instances - 1) // 4))


class TestTickInvariants:
    """Conservation/feasibility invariants of one `_tick` (the fluid step
    every policy shares)."""

    DT, QCAP = 0.5, 8.0

    def _tick_once(self, mk, seed=0, cap=1.25, x=None):
        import jax.numpy as jnp
        from repro.streams.simulator import _tick

        g = parallelize(mk(), seed=seed)
        sim = compile_sim(g, big_switch(8, cap), round_robin(g, 8))
        rng = np.random.default_rng(seed + 17)
        F = g.n_flows
        Qs = jnp.asarray(rng.uniform(0, self.QCAP, F), jnp.float32)
        Qr = jnp.asarray(rng.uniform(0, self.QCAP, F), jnp.float32)
        if x is None:
            x = jnp.asarray(rng.uniform(0, 5, F), jnp.float32)
        out = _tick(sim, Qs, Qr, x, self.DT, self.QCAP)
        return g, sim, np.asarray(Qs), np.asarray(Qr), np.asarray(x), out

    @pytest.mark.parametrize("mk", [trending_topics, trucking_iot])
    def test_transfer_window_and_accounting(self, mk):
        g, sim, Qs, Qr, x, out = self._tick_once(mk)
        Qs1, Qr1, transfer, drain = (np.asarray(a) for a in out[:4])
        tol = 1e-5
        # transfers: nonnegative, ≤ rate·dt, ≤ sender queue, ≤ receiver window
        assert transfer.min() >= -tol
        assert np.all(transfer <= x * self.DT + tol)
        assert np.all(transfer <= Qs + tol)
        assert np.all(Qr + transfer <= self.QCAP + tol)
        consume = np.asarray(drain) * self.DT
        assert consume.min() >= -tol
        assert np.all(consume <= Qr + transfer + tol)
        # receiver accounting: exact on non-droppable flows; droppable flows
        # may only *discard* (never mint) bytes
        drop = np.asarray(sim.droppable)
        raw_qr = Qr + transfer - consume
        np.testing.assert_allclose(Qr1[~drop], raw_qr[~drop], atol=1e-5)
        assert np.all(Qr1[drop] <= raw_qr[drop] + tol)
        # sender accounting: emitted bytes are bounded by selectivity·input
        # + generation (stall can only reduce them); droppable send queues
        # additionally *discard* stale bytes (negative apparent emission)
        emitted = Qs1 - Qs + transfer
        assert emitted[~drop].min() >= -tol
        M_in, w_out = np.asarray(sim.M_in), np.asarray(sim.w_out)
        sel = np.asarray(sim.selectivity)
        gen = np.asarray(sim.gen_rate)
        out_bound = sel * (M_in @ consume) + gen * self.DT
        by_inst = np.zeros(g.n_instances)
        np.add.at(by_inst, np.asarray(g.src_of_flow), emitted)
        assert np.all(by_inst <= w_out.sum(1) * out_bound + 1e-4)

    def test_appaware_rates_keep_links_feasible(self):
        # the appaware policy's x is link-feasible, so a tick's transfers are
        import jax.numpy as jnp
        from repro.core import FlowState
        from repro.core.allocator import allocate
        from repro.streams.simulator import INTERNAL_RATE

        g = parallelize(trending_topics(), seed=0)
        topo = big_switch(8, 1.25)
        sim = compile_sim(g, topo, round_robin(g, 8))
        rng = np.random.default_rng(5)
        F = g.n_flows
        st_ = FlowState(*[
            jnp.asarray(rng.uniform(0, 6, F), jnp.float32) for _ in range(5)
        ])
        x = allocate(sim.program, st_, dt=self.DT)
        x = jnp.where(sim.has_links, x, INTERNAL_RATE)
        _, _, _, _, (sink, _, _, load) = self._extracted_tick(sim, rng, x)
        assert np.all(np.asarray(load) <= topo.capacities * (1 + 1e-3))
        assert float(sink) >= -1e-6

    def _extracted_tick(self, sim, rng, x):
        import jax.numpy as jnp
        from repro.streams.simulator import _tick

        F = sim.R.shape[0]
        Qs = jnp.asarray(rng.uniform(0, self.QCAP, F), jnp.float32)
        Qr = jnp.asarray(rng.uniform(0, self.QCAP, F), jnp.float32)
        return _tick(sim, Qs, Qr, x, self.DT, self.QCAP)

    def test_closed_loop_byte_conservation(self):
        # selectivity-1 pipeline, ample capacity: every generated byte is
        # either delivered to the sink or still queued — nothing minted/lost
        import jax.numpy as jnp
        from repro.streams.simulator import INTERNAL_RATE, _tick

        app = StreamApp(
            "cons",
            [Operator("src", 1, gen_rate=0.8, proc_rate=100.0),
             Operator("mid", 2, proc_rate=100.0, selectivity=1.0),
             Operator("sink", 1, proc_rate=100.0, selectivity=0.0)],
            [Edge("src", "mid", Grouping.SHUFFLE),
             Edge("mid", "sink", Grouping.GLOBAL)],
        )
        g = parallelize(app, seed=0)
        sim = compile_sim(g, big_switch(4, 5.0), round_robin(g, 4))
        F = g.n_flows
        x = jnp.where(sim.has_links, 5.0, INTERNAL_RATE)
        Qs = Qr = jnp.zeros((F,), jnp.float32)
        delivered = 0.0
        T = 200
        for _ in range(T):
            Qs, Qr, _, _, (sink, _, _, _) = _tick(
                sim, Qs, Qr, x, self.DT, self.QCAP)
            delivered += float(sink)
        generated = 0.8 * self.DT * T
        total = delivered + float(jnp.sum(Qs) + jnp.sum(Qr))
        np.testing.assert_allclose(total, generated, rtol=1e-3)


class TestSimulator:
    def test_queue_and_throughput_invariants(self):
        g = parallelize(trending_topics(), seed=0)
        sim = compile_sim(g, big_switch(8, 1.25), round_robin(g, 8))
        r = simulate(sim, "tcp", seconds=120.0, dt=0.5)
        assert np.isfinite(r.sink_mb).all()
        assert (r.sink_mb >= -1e-6).all()
        # sink rate cannot exceed end-to-end production bound
        assert r.throughput_tps <= 1e6
        # no link ever exceeds its capacity
        assert (r.link_load <= r.caps[None, :] * (1 + 1e-3)).all()

    @pytest.mark.parametrize("mk", [trending_topics, trucking_iot])
    def test_appaware_beats_tcp_throughput(self, mk):
        g = parallelize(mk(), seed=0)
        sim = compile_sim(g, big_switch(8, 1.25), round_robin(g, 8))
        tcp = simulate(sim, "tcp", seconds=300.0, dt=0.5)
        aa = simulate(sim, "appaware", seconds=300.0, dt=0.5)
        assert aa.throughput_tps > tcp.throughput_tps * 1.10  # ≥ +10%

    def test_appaware_beats_tcp_multihop(self):
        # paper Fig. 9: bottleneck shifted to throttled internal links
        g = parallelize(trending_topics(), seed=0)
        topo = fat_tree(up=12.5).set_capacity(LinkKind.INTERNAL, 1.25)
        sim = compile_sim(g, topo, round_robin(g, topo.n_machines))
        tcp = simulate(sim, "tcp", seconds=300.0, dt=0.5)
        aa = simulate(sim, "appaware", seconds=300.0, dt=0.5)
        assert aa.throughput_tps > tcp.throughput_tps * 1.05
        # internal links never exceed throttled capacity
        internal = np.asarray(topo.link_kinds) == int(LinkKind.INTERNAL)
        assert (r := aa.link_load[:, internal].max()) <= 1.25 * (1 + 1e-3), r

    def test_bottleneck_free_parity(self):
        # paper §VI-B: with sufficient capacity both policies perform alike
        g = parallelize(trucking_iot(), seed=0)
        sim = compile_sim(g, big_switch(8, 125.0), round_robin(g, 8))
        tcp = simulate(sim, "tcp", seconds=200.0, dt=0.5)
        aa = simulate(sim, "appaware", seconds=200.0, dt=0.5)
        assert aa.throughput_tps == pytest.approx(tcp.throughput_tps, rel=0.05)

    def test_fixed_policy_and_motivation_gain(self):
        # brute-force style: the best fixed allocation beats TCP (Fig. 3)
        g = parallelize(motivation_chain(), seed=0)
        topo = big_switch(3, 1.25)
        # TP2-like placement: src+opB on m0 -> their flows share m0's uplink
        place = np.array([0, 1, 0, 2])
        sim = compile_sim(g, topo, place)
        tcp = simulate(sim, "tcp", seconds=200.0, dt=0.5)
        best = 0.0
        for w in np.linspace(0.1, 0.9, 9):
            x = np.array([w * 1.25, 1.25, (1 - w) * 1.25], np.float32)
            r = simulate(sim, "fixed", seconds=200.0, dt=0.5, x_fixed=x)
            best = max(best, r.throughput_tps)
        assert best >= tcp.throughput_tps

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_random_dags_stable(self, seed):
        rng = np.random.default_rng(seed)
        n_mid = int(rng.integers(1, 4))
        ops = [Operator("src", int(rng.integers(1, 3)), gen_rate=float(rng.uniform(0.5, 3.0)), proc_rate=100.0)]
        edges = []
        prev = "src"
        for k in range(n_mid):
            name = f"op{k}"
            ops.append(Operator(name, int(rng.integers(1, 4)), proc_rate=100.0,
                                selectivity=float(rng.uniform(0.3, 1.5)),
                                join=bool(rng.integers(0, 2))))
            edges.append(Edge(prev, name,
                              rng.choice([Grouping.SHUFFLE, Grouping.KEY, Grouping.GLOBAL]),
                              key_skew=float(rng.uniform(0, 1))))
            prev = name
        ops.append(Operator("sink", 1, proc_rate=100.0, selectivity=0.0))
        edges.append(Edge(prev, "sink", Grouping.GLOBAL))
        g = parallelize(StreamApp("rand", ops, edges), seed=seed)
        topo = big_switch(4, float(rng.uniform(0.5, 4.0)))
        sim = compile_sim(g, topo, round_robin(g, 4))
        for pol in ("tcp", "appaware"):
            r = simulate(sim, pol, seconds=60.0, dt=0.5)
            assert np.isfinite(r.sink_mb).all() and np.isfinite(r.latency).all()
            assert (r.link_load <= r.caps[None, :] * (1 + 1e-3)).all()
