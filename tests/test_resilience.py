"""Fault-tolerant campaign tests: deterministic fault injection, chunk
retry + quarantine, bisection, checkpoint/resume, teardown correctness,
and compile-boundary input validation.

The bitwise contract throughout: every recovery path re-runs scenarios
through the SAME per-bucket executable at the SAME padded row count as
the pipeline path, and vmap rows are independent — so every row the
resilience layer touches must come out byte-identical to the fault-free
campaign. (Fault-free campaign ≡ materialized run over the 4-policy
256-scenario suite is already pinned by
tests/test_campaign.py::TestStreamingParity with the guards at their
defaults, i.e. with the resilience layer enabled.)
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.net.topology import LinkSchedule
from repro.streams import (
    FailureRecord,
    FaultAbort,
    FaultPlan,
    FaultSpec,
    FleetRunner,
    InjectedFault,
    campaign_fleet,
    compile_fleet,
)

SECONDS = 6.0
DT = 0.5
CHUNK = 8
FAST = dict(retry_backoff_s=0.001, retry_backoff_cap_s=0.01)


@pytest.fixture(scope="module")
def corpus():
    """48 scenarios, mixed shapes/static/scheduled → several chunks per
    bucket at chunk_rows=8."""
    return compile_fleet(campaign_fleet(48, seed=0))


@pytest.fixture(scope="module")
def runner():
    """One shared runner: every test hits the same compiled executables
    (identical campaign parameters), so recovery re-runs are provably the
    same programs the pipeline dispatched."""
    return FleetRunner()


@pytest.fixture(scope="module")
def oracle(runner, corpus):
    """Fault-free campaign metrics — the bitwise reference."""
    cr = runner.run_campaign(corpus, "tcp", seconds=SECONDS, dt=DT,
                             chunk_rows=CHUNK)
    assert runner.last_stats["status"] == "ok"
    assert runner.last_stats["n_chunks"] >= 4
    return cr.metrics.copy()


def _campaign(runner, corpus, **kw):
    return runner.run_campaign(corpus, "tcp", seconds=SECONDS, dt=DT,
                               chunk_rows=CHUNK, **kw)


class TestFaultPlan:
    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown fault stage"):
            FaultSpec("h2d")
        with pytest.raises(ValueError, match="times"):
            FaultSpec("pack", times=0)
        with pytest.raises(ValueError, match="hang_s"):
            FaultSpec("dispatch", hang_s=1.0)

    def test_fire_consumes_and_logs(self):
        fp = FaultPlan([FaultSpec("dispatch", chunk=3, times=2)])
        fp.fire("dispatch", 0)          # wrong chunk: no-op
        fp.fire("pack", 3)              # wrong stage: no-op
        for _ in range(2):
            with pytest.raises(InjectedFault):
                fp.fire("dispatch", 3)
        fp.fire("dispatch", 3)          # spent: no-op
        assert fp.log == [("dispatch", 3, "raise")] * 2
        assert fp.n_fired("dispatch") == 2 and fp.n_fired("pack") == 0

    def test_random_is_reproducible(self):
        a = FaultPlan.random(7, n_chunks=10, n_scenarios=100)
        b = FaultPlan.random(7, n_chunks=10, n_scenarios=100)
        assert a.specs == b.specs and a.poison == b.poison
        assert a.poison and all(0 <= i < 100 for i in a.poison)

    def test_poison_mask(self):
        fp = FaultPlan(poison={2, 5})
        np.testing.assert_array_equal(fp.poison_mask([1, 2, 3, 5]),
                                      [False, True, False, True])


class TestInjectedStages:
    """One test per injected fault stage: the campaign recovers and every
    metric row stays bitwise-identical to the fault-free run."""

    @pytest.mark.parametrize("stage", ["pack", "transfer", "dispatch"])
    def test_transient_fault_recovers_bitwise(self, runner, corpus, oracle,
                                              stage):
        fp = FaultPlan([FaultSpec(stage, chunk=1, times=1)])
        cr = _campaign(runner, corpus, faults=fp, **FAST)
        stats = runner.last_stats
        assert stats["status"] == "ok"
        assert fp.n_fired(stage) == 1
        assert stats["n_recovered_chunks"] == 1
        assert not cr.failures
        np.testing.assert_array_equal(cr.metrics, oracle)

    def test_transfer_retry_then_succeed(self, runner, corpus, oracle):
        # ×2 transient: pipeline attempt + first sync retry fail, second
        # retry succeeds — no quarantine, bitwise metrics
        fp = FaultPlan([FaultSpec("transfer", chunk=2, times=2)])
        cr = _campaign(runner, corpus, faults=fp, **FAST)
        stats = runner.last_stats
        assert stats["status"] == "ok"
        assert fp.n_fired("transfer") == 2
        assert stats["n_retries"] >= 1
        assert not cr.failures
        np.testing.assert_array_equal(cr.metrics, oracle)

    @pytest.mark.timeout_s(120)
    def test_hung_transfer_watchdog(self, runner, corpus, oracle):
        # the transfer worker sleeps past transfer_timeout_s: the watchdog
        # abandons the executor and the chunk re-runs synchronously
        fp = FaultPlan([FaultSpec("transfer", chunk=1, times=1,
                                  hang_s=5.0)])
        cr = _campaign(runner, corpus, faults=fp, transfer_timeout_s=0.25,
                       **FAST)
        stats = runner.last_stats
        assert stats["status"] == "ok"
        assert stats["n_recovered_chunks"] == 1
        assert not cr.failures
        np.testing.assert_array_equal(cr.metrics, oracle)

    def test_nan_epilogue_quarantined(self, runner, corpus, oracle):
        poisoned = 9
        fp = FaultPlan(poison={poisoned})
        cr = _campaign(runner, corpus, faults=fp, **FAST)
        assert runner.last_stats["status"] == "ok"
        assert np.isnan(cr.metrics[poisoned]).all()
        assert [f.scenario for f in cr.failures] == [poisoned]
        assert cr.failures[0].stage == "non_finite"
        np.testing.assert_array_equal(cr.quarantined, [poisoned])
        ok = np.arange(len(corpus)) != poisoned
        np.testing.assert_array_equal(cr.metrics[ok], oracle[ok])

    def test_retries_exhausted_quarantines_chunk(self, runner, corpus,
                                                 oracle):
        # permanently broken dispatch for chunk 0: retries exhaust, then
        # bisection exhausts — every scenario of that chunk quarantined
        # with the injected stage in its FailureRecord; the rest bitwise
        fp = FaultPlan([FaultSpec("dispatch", chunk=0, times=-1)])
        cr = _campaign(runner, corpus, faults=fp, max_retries=1, **FAST)
        stats = runner.last_stats
        assert stats["status"] == "ok"
        assert cr.failures and all(f.stage == "dispatch" and f.attempts > 1
                                   for f in cr.failures)
        bad = cr.quarantined
        assert len(bad) == stats["n_quarantined"] > 0
        assert np.isnan(cr.metrics[bad]).all()
        ok = np.ones(len(corpus), bool)
        ok[bad] = False
        np.testing.assert_array_equal(cr.metrics[ok], oracle[ok])


class TestBisection:
    def test_isolates_exactly_poisoned_in_mixed_chunk(self, runner, corpus,
                                                      oracle):
        # two poisoned scenarios landing in the same chunk plus one
        # elsewhere: bisection must quarantine exactly those three
        poisoned = {8, 10, 30}
        fp = FaultPlan(poison=poisoned)
        cr = _campaign(runner, corpus, faults=fp, **FAST)
        np.testing.assert_array_equal(cr.quarantined, sorted(poisoned))
        for i in poisoned:
            assert np.isnan(cr.metrics[i]).all()
        ok = np.ones(len(corpus), bool)
        ok[list(poisoned)] = False
        np.testing.assert_array_equal(cr.metrics[ok], oracle[ok])
        assert {f.scenario for f in cr.failures} == poisoned

    def test_finite_check_off_lets_nan_through(self, runner, corpus):
        # guard knob: with finite_check=False poisoned rows are recorded
        # as-is (NaN) but nothing is quarantined or re-run
        fp = FaultPlan(poison={3})
        cr = _campaign(runner, corpus, faults=fp, finite_check=False,
                       **FAST)
        assert np.isnan(cr.metrics[3]).all()
        assert not cr.failures
        assert runner.last_stats["n_recovered_chunks"] == 0


class TestAcceptance:
    """The ISSUE's headline scenario at full campaign scale."""

    def test_256_campaign_transient_plus_poison(self):
        sims = compile_fleet(campaign_fleet(256, seed=0))
        runner = FleetRunner()
        base = runner.run_campaign(sims, "tcp", seconds=SECONDS, dt=DT,
                                   chunk_rows=32)
        fp = FaultPlan([FaultSpec("transfer", times=2)], poison={100})
        cr = runner.run_campaign(sims, "tcp", seconds=SECONDS, dt=DT,
                                 chunk_rows=32, faults=fp, **FAST)
        assert runner.last_stats["status"] == "ok"
        assert fp.n_fired("transfer") == 2
        np.testing.assert_array_equal(cr.quarantined, [100])
        assert np.isnan(cr.metrics[100]).all()
        assert [f.scenario for f in cr.failures] == [100]
        ok = np.arange(256) != 100
        np.testing.assert_array_equal(cr.metrics[ok], base.metrics[ok])


class TestCheckpointResume:
    def test_kill_then_resume_bitwise(self, runner, corpus, oracle,
                                      tmp_path):
        ck = str(tmp_path / "ck")
        n_chunks = runner.last_stats["n_chunks"]
        # kill at the last chunk: by then the pipeline has collected (and
        # checkpointed) all but the ~2 chunks still in flight
        fp = FaultPlan([FaultSpec("abort", chunk=n_chunks - 1)])
        with pytest.raises(FaultAbort):
            _campaign(runner, corpus, faults=fp, checkpoint=ck)
        killed = runner.last_stats
        assert killed["status"] == "failed"
        assert "FaultAbort" in killed["error"]
        assert 0 < killed["n_chunks_done"] < n_chunks
        done = killed["n_chunks_done"]
        # resume: completed chunks restore bitwise without re-dispatching
        cr = _campaign(runner, corpus, checkpoint=ck)
        stats = runner.last_stats
        assert stats["status"] == "ok"
        assert stats["n_chunks_resumed"] == done
        assert stats["n_dispatches"] == n_chunks - done < n_chunks
        np.testing.assert_array_equal(cr.metrics, oracle)

    def test_completed_campaign_resumes_with_zero_dispatches(
            self, runner, corpus, oracle, tmp_path):
        ck = str(tmp_path / "ck")
        cr1 = _campaign(runner, corpus, checkpoint=ck)
        assert runner.last_stats["n_dispatches"] > 0
        cr2 = _campaign(runner, corpus, checkpoint=ck)
        stats = runner.last_stats
        assert stats["n_dispatches"] == 0
        assert stats["n_chunks_resumed"] == stats["n_chunks"]
        np.testing.assert_array_equal(cr2.metrics, cr1.metrics)
        np.testing.assert_array_equal(cr2.metrics, oracle)

    def test_failures_survive_resume(self, runner, corpus, tmp_path):
        ck = str(tmp_path / "ck")
        fp = FaultPlan(poison={5})
        cr1 = _campaign(runner, corpus, faults=fp, checkpoint=ck, **FAST)
        assert cr1.quarantined.tolist() == [5]
        cr2 = _campaign(runner, corpus, checkpoint=ck)
        assert runner.last_stats["n_dispatches"] == 0
        assert [f.scenario for f in cr2.failures] == [5]
        assert isinstance(cr2.failures[0], FailureRecord)
        np.testing.assert_array_equal(cr2.metrics, cr1.metrics)

    def test_fingerprint_mismatch_ignores_checkpoint(self, runner, corpus,
                                                     tmp_path):
        ck = str(tmp_path / "ck")
        _campaign(runner, corpus, checkpoint=ck)
        # different policy ⇒ different fingerprint ⇒ full re-run
        runner.run_campaign(corpus, "appaware", seconds=SECONDS, dt=DT,
                            chunk_rows=CHUNK, checkpoint=ck)
        stats = runner.last_stats
        assert stats["n_chunks_resumed"] == 0
        assert stats["n_dispatches"] == stats["n_chunks"]
        # checkpoint dir now serves both campaigns, keyed by fingerprint
        names = os.listdir(ck)
        assert sum(n.endswith(".npy") for n in names) == 2 * stats["n_chunks"]

    def test_checkpoint_rejects_trajectories(self, runner, corpus,
                                             tmp_path):
        with pytest.raises(ValueError, match="retain_trajectories"):
            _campaign(runner, corpus, checkpoint=str(tmp_path / "ck"),
                      retain_trajectories=True)


class TestTeardown:
    """Satellite: failure-aware `last_stats` + clean pipeline reset."""

    def test_failed_stats_regression(self, runner, corpus, oracle):
        sentinel = {"marker": "previous run"}
        runner.last_stats = sentinel
        fp = FaultPlan([FaultSpec("abort", chunk=2)])
        with pytest.raises(FaultAbort):
            _campaign(runner, corpus, faults=fp)
        stats = runner.last_stats
        assert stats is not sentinel, "failed run left stale last_stats"
        assert stats["mode"] == "campaign"
        assert stats["status"] == "failed"
        assert "FaultAbort" in stats["error"]
        assert stats["n_chunks_done"] < stats["n_chunks"]
        # per-run pipeline state was reset: the very next campaign is
        # clean and bitwise-correct on the same runner
        assert not runner._campaign_bufs
        cr = _campaign(runner, corpus)
        assert runner.last_stats["status"] == "ok"
        assert runner.last_stats["error"] is None
        np.testing.assert_array_equal(cr.metrics, oracle)

    def test_fault_free_stats_report_ok(self, runner, corpus):
        _campaign(runner, corpus)
        stats = runner.last_stats
        assert stats["status"] == "ok" and stats["error"] is None
        assert stats["n_chunks_done"] == stats["n_chunks"]
        assert stats["n_dispatches"] == stats["n_chunks"]
        assert stats["n_retries"] == 0 == stats["n_quarantined"]


class TestInputValidation:
    """Satellite: compile_sim / pad_sim reject poisoned fields by name."""

    @staticmethod
    def _scenario():
        return campaign_fleet(6, seed=0)[0]

    def test_nan_capacity_rejected(self):
        scn = self._scenario()
        scn.topo.links[0] = dataclasses.replace(scn.topo.links[0],
                                                capacity=np.nan)
        with pytest.raises(ValueError, match="capacities"):
            scn.compile()

    def test_negative_capacity_rejected(self):
        scn = self._scenario()
        scn.topo.links[0] = dataclasses.replace(scn.topo.links[0],
                                                capacity=-5.0)
        with pytest.raises(ValueError, match="capacities"):
            scn.compile()

    def test_nan_demand_rejected(self):
        scn = self._scenario()
        scn.graph.gen_rate[0] = np.nan
        with pytest.raises(ValueError, match="gen_rate"):
            scn.compile()

    def test_negative_demand_rejected(self):
        scn = self._scenario()
        scn.graph.gen_rate[0] = -1.0
        with pytest.raises(ValueError, match="gen_rate"):
            scn.compile()

    def test_nan_proc_rate_rejected_inf_allowed(self):
        scn = self._scenario()
        scn.graph.proc_rate[0] = np.inf   # load-bearing: "unbounded"
        scn.compile()
        scn.graph.proc_rate[0] = np.nan
        with pytest.raises(ValueError, match="proc_rate"):
            scn.compile()

    @pytest.mark.parametrize("field", ["ev_t0", "ev_t1"])
    def test_bad_event_times_rejected_inf_allowed(self, field):
        scn = self._scenario()
        sch = LinkSchedule.empty(scn.topo.n_links).with_event(
            0, t0=5.0, t1=np.inf, scale=0.5)  # inf t1 = permanent: fine
        scn = dataclasses.replace(scn, schedule=sch)
        scn.compile()
        for bad in (np.nan, -1.0):
            broken = dataclasses.replace(
                sch, **{field: np.array([bad], np.float32)})
            with pytest.raises(ValueError, match=field):
                dataclasses.replace(scn, schedule=broken).compile()

    def test_bad_event_scale_rejected(self):
        scn = self._scenario()
        sch = LinkSchedule.empty(scn.topo.n_links).with_event(
            0, t0=5.0, scale=0.5)
        for bad in (np.nan, np.inf, -0.5):
            broken = dataclasses.replace(
                sch, ev_scale=np.array([bad], np.float32))
            with pytest.raises(ValueError, match="ev_scale"):
                dataclasses.replace(scn, schedule=broken).compile()

    def test_pad_sim_rejects_poisoned_compiled_fields(self, corpus):
        from repro.streams import FleetShape, pad_sim
        sim = corpus[0]
        shape = FleetShape.cover([sim])
        bad_caps = np.asarray(sim.caps).copy()
        bad_caps[0] = np.nan
        with pytest.raises(ValueError, match="caps"):
            pad_sim(dataclasses.replace(sim, caps=bad_caps), shape)
        # a *dynamic* member has events to poison
        dyn = next(s for s in corpus if np.asarray(s.ev_t0).size)
        bad_ev = np.asarray(dyn.ev_t0).copy()
        bad_ev[0] = -2.0
        with pytest.raises(ValueError, match="ev_t0"):
            pad_sim(dataclasses.replace(dyn, ev_t0=bad_ev),
                    FleetShape.cover([dyn]))
