"""Crossover dispatch + order cache tests for the fused max-min solver.

The solver carries two water-level forms behind a trace-time crossover on
the (padded) flow count (`MAXMIN_CROSSOVER_F`): the rank-prefix GEMM form
(order-only left operand, cacheable across ticks) and the argsort+cumsum
form. This suite pins:

  * form parity — both forms agree ≤ 1e-5 at shapes straddling the
    crossover, including the degenerate edges (zero demand, single flow,
    all-tied demands);
  * static dispatch — form selection is a python-level branch on a static
    shape, so sweeping demands/capacities at a fixed shape never grows the
    jit cache (no-recompile);
  * the order cache — `maxmin_fused_step` is bitwise-identical to the
    fresh `maxmin_fused` solve whatever the carry's hit pattern, rebuilds
    exactly when the demand *order* changes (once, on the first tick, for
    static demands), and the blocked GEMM variant matches the single-pass
    one.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.tcp import (
    MAXMIN_CROSSOVER_F,
    maxmin_fused,
    maxmin_fused_step,
    maxmin_order_init,
)

ATOL = 1e-5


def _instance(seed, F, L, max_links=4):
    rng = np.random.default_rng(seed)
    R = np.zeros((F, L), np.float32)
    for f in range(F):
        k = int(rng.integers(0, min(L, max_links) + 1))
        if k:
            R[f, rng.choice(L, k, replace=False)] = 1.0
    cap = rng.uniform(0.0, 20.0, L).astype(np.float32)
    d = rng.uniform(0.0, 10.0, F).astype(np.float32)
    return R, cap, d


def _forms(R, cap, d, **kw):
    a = np.asarray(maxmin_fused(jnp.asarray(R), jnp.asarray(cap),
                                jnp.asarray(d), form="gemm", **kw))
    b = np.asarray(maxmin_fused(jnp.asarray(R), jnp.asarray(cap),
                                jnp.asarray(d), form="sorted", **kw))
    return a, b


class TestFormParity:
    # shapes straddling the crossover: well below, just below, at, above
    @pytest.mark.parametrize("F,L", [
        (12, 8),
        (MAXMIN_CROSSOVER_F - 1, 24),
        (MAXMIN_CROSSOVER_F, 24),
        (MAXMIN_CROSSOVER_F + 61, 32),
    ])
    def test_forms_agree_across_crossover(self, F, L):
        for seed in (0, 1):
            R, cap, d = _instance(seed, F, L)
            a, b = _forms(R, cap, d)
            np.testing.assert_allclose(a, b, atol=ATOL,
                                       rtol=ATOL * np.maximum(a, 1.0).max())

    def test_zero_demand(self):
        R = np.ones((6, 3), np.float32)
        cap = np.full(3, 4.0, np.float32)
        a, b = _forms(R, cap, np.zeros(6, np.float32))
        np.testing.assert_allclose(a, 0.0, atol=ATOL)
        np.testing.assert_allclose(b, 0.0, atol=ATOL)

    def test_single_flow(self):
        R = np.array([[1.0, 1.0]], np.float32)
        cap = np.array([2.0, 5.0], np.float32)
        a, b = _forms(R, cap, np.array([9.0], np.float32))
        assert a[0] == pytest.approx(2.0, abs=ATOL)
        assert b[0] == pytest.approx(2.0, abs=ATOL)

    def test_all_tied_demands(self):
        # every demand identical: the order machinery sees nothing but
        # index tie-breaks — both forms must produce the equal split
        F = 8
        R = np.ones((F, 1), np.float32)
        cap = np.array([4.0], np.float32)
        d = np.full(F, 3.0, np.float32)
        a, b = _forms(R, cap, d)
        np.testing.assert_allclose(a, 0.5, atol=ATOL)
        np.testing.assert_allclose(b, 0.5, atol=ATOL)

    def test_blocked_gemm_matches_single_pass(self):
        for F, L in [(96, 16), (200, 32)]:
            R, cap, d = _instance(2, F, L)
            a = np.asarray(maxmin_fused(
                jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d),
                form="gemm", block_flows=0))        # 0 → force single-pass
            b = np.asarray(maxmin_fused(
                jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d),
                form="gemm", block_flows=32))
            np.testing.assert_allclose(
                a, b, atol=ATOL, rtol=ATOL * np.maximum(a, 1.0).max())

    def test_auto_dispatch_matches_forced_form(self):
        # the default (form=None) must equal the side of the crossover the
        # static flow count selects — below: gemm, at/above: sorted
        R, cap, d = _instance(3, 20, 10)
        auto = np.asarray(maxmin_fused(jnp.asarray(R), jnp.asarray(cap),
                                       jnp.asarray(d)))
        gemm = np.asarray(maxmin_fused(jnp.asarray(R), jnp.asarray(cap),
                                       jnp.asarray(d), form="gemm"))
        np.testing.assert_array_equal(auto, gemm)
        F = MAXMIN_CROSSOVER_F
        R, cap, d = _instance(4, F, 16)
        auto = np.asarray(maxmin_fused(jnp.asarray(R), jnp.asarray(cap),
                                       jnp.asarray(d)))
        srt = np.asarray(maxmin_fused(jnp.asarray(R), jnp.asarray(cap),
                                      jnp.asarray(d), form="sorted"))
        np.testing.assert_array_equal(auto, srt)


class TestStaticDispatch:
    def test_no_recompile_across_value_sweep(self):
        # dispatch is decided by *shape* at trace time: sweeping values at
        # one shape compiles exactly one executable per shape
        F, L = 16, 8
        R, cap, d = _instance(5, F, L)
        maxmin_fused(jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d))
        n0 = maxmin_fused._cache_size()
        rng = np.random.default_rng(9)
        for _ in range(5):
            d2 = rng.uniform(0.0, 10.0, F).astype(np.float32)
            c2 = rng.uniform(0.1, 20.0, L).astype(np.float32)
            maxmin_fused(jnp.asarray(R), jnp.asarray(c2), jnp.asarray(d2))
        assert maxmin_fused._cache_size() == n0


class TestOrderCache:
    def test_step_bitwise_matches_fresh(self):
        # whatever the carry's hit pattern — first-tick rebuild, kept
        # order, genuine order change — the step output is bitwise equal
        # to a fresh solve on the same inputs
        rng = np.random.default_rng(11)
        for seed in range(6):
            F = int(rng.integers(2, 24))
            L = int(rng.integers(2, 16))
            R, cap, d = _instance(seed, F, L)
            carry = maxmin_order_init(F)
            for k in range(8):
                if k in (3, 6):
                    d = rng.uniform(0.0, 10.0, F).astype(np.float32)
                else:
                    d = (d * np.float32(1.002)).astype(np.float32)
                x, carry, _ = maxmin_fused_step(
                    jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d), carry)
                ref = maxmin_fused(jnp.asarray(R), jnp.asarray(cap),
                                   jnp.asarray(d))
                np.testing.assert_array_equal(np.asarray(x), np.asarray(ref))

    def test_rebuild_counting(self):
        # monotone rescaling preserves the demand order → no rebuild;
        # swapping two demands breaks it → exactly one rebuild. Every flow
        # is on-net (the solver zeroes off-net demands, which would mask
        # an order change involving them).
        F, L = 10, 6
        rng = np.random.default_rng(7)
        R = np.zeros((F, L), np.float32)
        for f in range(F):
            R[f, rng.choice(L, 2, replace=False)] = 1.0
        cap = rng.uniform(1.0, 20.0, L).astype(np.float32)
        d = np.sort(rng_unique(F))           # strictly increasing, no ties
        carry = maxmin_order_init(F)
        _, carry, reb0 = maxmin_fused_step(
            jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d), carry)
        assert bool(reb0)                    # first tick always rebuilds
        _, carry, reb1 = maxmin_fused_step(
            jnp.asarray(R), jnp.asarray(cap),
            jnp.asarray(d * np.float32(2.0)), carry)
        assert not bool(reb1)                # order preserved → kept
        d2 = d.copy()
        d2[0], d2[-1] = d[-1], d[0]          # order broken
        _, carry, reb2 = maxmin_fused_step(
            jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d2), carry)
        assert bool(reb2)

    def test_static_demand_scan_rebuilds_once(self):
        # the perf-gate invariant, in miniature: constant demands over a
        # scan rebuild the order operand exactly once (tick 0)
        F, L = 12, 8
        R, cap, d = _instance(8, F, L)

        def step(carry, _):
            _, carry, reb = maxmin_fused_step(
                jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d), carry)
            return carry, reb

        _, rebs = jax.lax.scan(step, maxmin_order_init(F), None, length=32)
        assert int(np.sum(np.asarray(rebs))) == 1

    def test_step_under_vmap_matches_fresh(self):
        # the fleet path: batched step (cond lowers to select) must still
        # be bitwise-identical to per-member fresh solves
        B, F, L = 6, 14, 10
        rng = np.random.default_rng(13)
        R = np.zeros((B, F, L), np.float32)
        for b in range(B):
            for f in range(F):
                R[b, f, rng.choice(L, 3, replace=False)] = 1.0
        cap = rng.uniform(1.0, 8.0, (B, L)).astype(np.float32)
        d = rng.uniform(0.0, 5.0, (B, F)).astype(np.float32)

        def one(R1, c1, d1):
            carry = maxmin_order_init(F)
            x1, carry, _ = maxmin_fused_step(R1, c1, d1, carry)
            x2, _, _ = maxmin_fused_step(R1, c1, d1 * 1.5, carry)
            return x1, x2

        x1, x2 = jax.jit(jax.vmap(one))(jnp.asarray(R), jnp.asarray(cap),
                                        jnp.asarray(d))
        for b in range(B):
            np.testing.assert_array_equal(
                np.asarray(x1[b]),
                np.asarray(maxmin_fused(jnp.asarray(R[b]),
                                        jnp.asarray(cap[b]),
                                        jnp.asarray(d[b]))))
            np.testing.assert_array_equal(
                np.asarray(x2[b]),
                np.asarray(maxmin_fused(jnp.asarray(R[b]),
                                        jnp.asarray(cap[b]),
                                        jnp.asarray(d[b] * 1.5))))


def rng_unique(F, seed=17):
    """F strictly distinct positive float32 demands."""
    vals = np.random.default_rng(seed).uniform(0.5, 10.0, 4 * F)
    return np.unique(vals.astype(np.float32))[:F].astype(np.float32)
