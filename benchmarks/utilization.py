"""Fig. 12: bottleneck-link utilization — App-aware must stay close to TCP
(paper: 99% / 97% vs TCP). The allocator's backfill pass (§VI-C) is what
keeps it work-conserving."""
from __future__ import annotations

from benchmarks.common import CAPS, emit, run_pair, singlehop_topo
from repro.streams import trending_topics, trucking_iot


def run() -> list[dict]:
    rows = []
    for app_name, app_fn in (("TT", trending_topics), ("TI", trucking_iot)):
        for cap_name, cap in CAPS.items():
            tcp, aa = run_pair(app_fn, singlehop_topo(cap))
            rows.append({
                "name": f"fig12_utilization_{app_name}_{cap_name}",
                "tcp_util": round(tcp.bottleneck_utilization(), 3),
                "appaware_util": round(aa.bottleneck_utilization(), 3),
            })
    return rows


def main() -> None:
    emit(run(), "fig12")


if __name__ == "__main__":
    main()
