"""Fig. 3 (motivation): three placements of a 4-operator chain; TCP vs the
best fixed bandwidth allocation found by brute-force search. Paper: BA beats
TCP by 17% / 47% / 33% for TP1/TP2/TP3 — placement alone is not enough."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DT, emit
from repro.net import big_switch
from repro.streams import compile_sim, motivation_chain, parallelize, simulate

# three placements over 3 machines (instances: src, opA, opB, sink)
PLACEMENTS = {
    "TP1": np.array([0, 1, 2, 1]),   # chain spread; src->A & B->sink disjoint
    "TP2": np.array([0, 1, 0, 2]),   # src+opB co-located -> shared uplink m0
    "TP3": np.array([0, 0, 1, 2]),   # src+opA co-located; A->B & B->sink mix
}
CAP = 1.25
SECONDS = 300.0


def brute_force_best(sim, n_flows: int, grid: int = 7) -> float:
    """Grid-search fixed rate vectors over the flows (the paper's costly
    exhaustive search; small topology makes it feasible)."""
    best = 0.0
    ws = np.linspace(0.1, 1.0, grid)
    from itertools import product
    for w in product(ws, repeat=n_flows):
        x = np.asarray(w, np.float32) * CAP
        r = simulate(sim, "fixed", seconds=SECONDS, dt=DT, x_fixed=x)
        best = max(best, r.throughput_tps)
    return best


def run(fast: bool = True) -> list[dict]:
    rows = []
    g = parallelize(motivation_chain(), seed=0)
    topo = big_switch(3, CAP)
    for name, place in PLACEMENTS.items():
        sim = compile_sim(g, topo, place)
        tcp = simulate(sim, "tcp", seconds=SECONDS, dt=DT)
        grid = 5 if fast else 9
        best = brute_force_best(sim, g.n_flows, grid=grid)
        # the online allocator should recover most of the brute-force gain
        aa = simulate(sim, "appaware", seconds=SECONDS, dt=DT)
        rows.append({
            "name": f"fig3_motivation_{name}",
            "tcp_tps": round(tcp.throughput_tps, 1),
            "bruteforce_tps": round(best, 1),
            "appaware_tps": round(aa.throughput_tps, 1),
            "ba_gain_pct": round((best / max(tcp.throughput_tps, 1e-9) - 1)
                                 * 100, 1),
        })
    return rows


def main() -> None:
    emit(run(), "fig3")


if __name__ == "__main__":
    main()
