"""Fleet engine benchmark: packed single-dispatch `simulate_many`
(`FleetRunner`) vs a sequential `simulate` loop over the same scenarios.

The sequential loop pays one XLA compile per distinct [F, L, I] shape plus
per-scenario dispatch; the packed path compiles ONE fused executable per
policy (every shape bucket's vmap-over-scan inside the same program) and a
warm fleet run is exactly one kernel dispatch. Reports end-to-end
wall-clock for the cold path (first call, compiles included — the
realistic "run a fresh study" cost) and the steady-state warm path, plus
the runner's dispatch/bucket stats so the single-dispatch property is
recorded next to the timing it buys. Warm timings are the **median of
WARM_REPS repeat calls, with the sequential and batched reps
interleaved**: post-compile calls are tens of milliseconds, where
single-shot wall-clock on a shared CI core is noise-dominated and
container drift between separate timing blocks would bias the ratio.

The `fleet_dispatch_floor` row measures the same no-solver "fixed" run at
1, 2 and 4 kernel dispatches. The 1- and 4-dispatch points share one
identical 4-bucket plan (the packed executable vs per-bucket dispatch of
the same buckets — same compute, only the launch count changes), so
`(t_4 - t_1) / 3` isolates per-dispatch overhead; the 2-dispatch point is
a *merged* 2-bucket plan whose larger covers add padded compute, recorded
as the intermediate operating point rather than a fit input. This keeps
the overhead the packing amortizes measured and tracked across PRs, and
gives the planner's `TICK_OVERHEAD_FLOPS` calibration (see
`repro.streams.fleet`) a checked-in measurement trail.

On CPU the scenario axis is additionally split across forced XLA host
devices (one per core, up to 8) via the runner's plain-SPMD sharding —
set BEFORE jax initializes, hence the env fiddling above the imports.

    PYTHONPATH=src python benchmarks/fleet.py
"""
from __future__ import annotations

import os
import sys
import time

if "jax" not in sys.modules:  # too late to force devices otherwise
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={min(os.cpu_count() or 1, 8)}"
    )

import jax
import numpy as np

from benchmarks.common import emit
from repro.streams import (
    FleetRunner,
    bench_fleet,
    campaign_fleet,
    compile_fleet,
    link_failure_sweep,
    simulate,
    simulate_many,
    time_varying_sweep,
)
from repro.streams.fleet import (
    TICK_OVERHEAD_FLOPS_CPU,
    _default_runner,
    calibrate_backend,
)

SECONDS = 60.0
DT = 0.5
WARM_REPS = 5


def _wall(fn):
    t0 = time.time()
    out = fn()
    return time.time() - t0, out


def _wall_median(fn, reps: int):
    ts, out = [], None
    for _ in range(reps):
        t, out = _wall(fn)
        ts.append(t)
    return float(np.median(ts)), out


def run(policy: str = "appaware", seconds: float = SECONDS) -> list[dict]:
    sims = compile_fleet(bench_fleet(seed=0))

    def sequential():
        return [simulate(s, policy, seconds=seconds, dt=DT) for s in sims]

    def batched():
        return simulate_many(sims, policy, seconds=seconds, dt=DT)

    # cold: includes compilation — what one pays for a fresh parameter study
    t_seq_cold, _ = _wall(sequential)
    t_bat_cold, _ = _wall(batched)
    # warm: compile caches hot, pure execution. Sequential and batched
    # reps are INTERLEAVED so slow container drift (a shared CI core
    # speeding up or down between blocks) cancels out of the ratio instead
    # of biasing it; each side still reports its median over WARM_REPS.
    seq_ts, bat_ts, seq, bat = [], [], None, None
    for _ in range(WARM_REPS):
        t, seq = _wall(sequential)
        seq_ts.append(t)
        t, bat = _wall(batched)
        bat_ts.append(t)
    t_seq_warm = float(np.median(seq_ts))
    t_bat_warm = float(np.median(bat_ts))
    stats = _default_runner().last_stats

    # sanity: batched results match the sequential loop
    worst = max(
        abs(a.throughput_tps - b.throughput_tps)
        for a, b in zip(seq, bat)
    )

    return [{
        "name": f"fleet_{policy}",
        "us_per_call": t_bat_warm * 1e6,
        "n_scenarios": len(sims),
        "backend": jax.default_backend(),
        "seq_cold_s": round(t_seq_cold, 2),
        "batch_cold_s": round(t_bat_cold, 2),
        "speedup_cold": round(t_seq_cold / t_bat_cold, 2),
        "seq_warm_s": round(t_seq_warm, 3),
        "batch_warm_s": round(t_bat_warm, 3),
        "speedup_warm": round(t_seq_warm / t_bat_warm, 2),
        "warm_ms_per_scenario": round(t_bat_warm * 1e3 / len(sims), 3),
        "n_dispatches": stats["n_dispatches"],
        "n_buckets": stats["n_buckets"],
        "max_tps_diff": f"{worst:.2e}",
        # tcp only: demand-order cache rebuilds across the whole fleet
        # (queue-driven demands reorder freely, so this is an observable,
        # not a gate — the gated invariant is the static-demand row)
        "order_rebuilds": stats.get("order_rebuilds", 0),
    }]


def run_order_cache(n_ticks: int = 64) -> list[dict]:
    """Order-cache invariant row (gated by perf_gate): scanning the
    order-cached solver (`maxmin_fused_step`) over every corpus scenario's
    routing/capacities with a CONSTANT demand vector must rebuild the rank
    operand exactly once per scenario — the tick-0 cold start. More than
    one rebuild means the monotonicity check is spuriously invalidating a
    carried order; zero means the cold start isn't counted. The real tcp
    fleet's queue-driven demands reorder freely (their rebuild count is
    reported in the ``fleet_tcp`` row as an observable), so the invariant
    is pinned on static demands where the ground truth is exact."""
    import jax.numpy as jnp

    from repro.core.tcp import maxmin_fused_step, maxmin_order_init

    sims = compile_fleet(bench_fleet(seed=0))
    rng = np.random.default_rng(3)
    per = []
    for s in sims:
        R = jnp.asarray(s.R)
        cap = jnp.asarray(s.caps)
        F = int(R.shape[0])
        d = jnp.asarray(rng.uniform(
            0.0, 2.0 * float(np.asarray(s.caps).max()), F), jnp.float32)

        def step(carry, _):
            _, carry, reb = maxmin_fused_step(R, cap, d, carry)
            return carry, reb

        _, rebs = jax.lax.scan(step, maxmin_order_init(F), None,
                               length=n_ticks)
        per.append(int(np.sum(np.asarray(rebs))))
    # no us_per_call: this is an invariant/observable row, not a timing —
    # common.emit prints "-" and rejects fake 0.0 timings outright
    return [{
        "name": "fleet_order_cache",
        "n_scenarios": len(sims),
        "backend": jax.default_backend(),
        "ticks_per_scenario": n_ticks,
        "static_demand_rebuilds_total": int(sum(per)),
        "static_demand_rebuilds_max": int(max(per)),
        "static_demand_rebuilds_min": int(min(per)),
        "rebuilds_per_scenario_expected": 1,
    }]


def run_dispatch_floor(seconds: float = SECONDS) -> list[dict]:
    """No-solver "fixed" corpus run at 1, 2 and 4 kernel dispatches.

    The 1- and 4-dispatch points run the *same* flop-only 4-bucket plan
    padded the same way, so their difference isolates per-dispatch
    overhead with identical compute: ``per_dispatch_overhead_s =
    (t_4 - t_1) / 3``. The 2-dispatch point is a merged 2-bucket plan —
    its larger covers add padded compute, so it is the intermediate
    *operating* point, not a fit input. The separate ``packed_default_s``
    point is the overhead-aware planner's own choice for this fleet (it
    collapses cheap-tick fleets below the bucket cap), i.e. what
    `simulate_many` actually pays."""
    sims = compile_fleet(bench_fleet(seed=0))
    xf = [np.full(s.R.shape[0], 0.5, np.float32) for s in sims]

    def timed(runner):
        def call():
            return runner.run(sims, "fixed", seconds=seconds, dt=DT,
                              x_fixed=xf)
        call()  # compile
        t, _ = _wall_median(call, WARM_REPS)
        return t, runner.last_stats

    t1, s1 = timed(FleetRunner(fused=True, max_buckets=4, tick_overhead=0.0))
    t2, s2 = timed(FleetRunner(fused=False, max_buckets=2,
                               tick_overhead=0.0))
    t4, s4 = timed(FleetRunner(fused=False, max_buckets=4,
                               tick_overhead=0.0))
    tp, sp = timed(FleetRunner())   # overhead-aware default, packed
    assert (s1["n_dispatches"], s2["n_dispatches"], s4["n_dispatches"]) \
        == (1, 2, 4)
    return [{
        "name": "fleet_dispatch_floor",
        "us_per_call": t1 * 1e6,
        "n_scenarios": len(sims),
        "backend": jax.default_backend(),
        "dispatch_1_s": round(t1, 4),
        "dispatch_2_s": round(t2, 4),
        "dispatch_4_s": round(t4, 4),
        "per_dispatch_overhead_s": round((t4 - t1) / 3, 4),
        "packed_default_s": round(tp, 4),
        "packed_default_buckets": sp["n_buckets"],
        # measured per-backend calibration (what the planner and
        # `chunk_rows="auto"` actually use); the old hardcoded guess
        # stays recorded as the REPRO_CALIBRATE=0 fallback
        "planner_tick_overhead_flops": calibrate_backend(
        ).tick_overhead_flops,
        "planner_tick_overhead_fallback": TICK_OVERHEAD_FLOPS_CPU,
    }]


def run_dynamics(policy: str = "tcp", seconds: float = SECONDS) -> list[dict]:
    """Scheduled-caps machinery cost vs static: the *identical* scenarios
    once with no schedule and once with a constant (no-op) schedule. A
    constant schedule produces bitwise-identical trajectories but takes
    the full dynamic path — [T, L] capacity stream into the scan plus
    per-tick enforcement — so the ratio isolates exactly what in-run
    dynamics cost, with zero workload difference (a real failure schedule
    would also change queue dynamics and the max-min solver's
    data-dependent trip counts, conflating workload with machinery)."""
    import dataclasses

    from repro.net import LinkSchedule

    scens = (link_failure_sweep(n=4, seed=7, in_run=True)
             + time_varying_sweep(n_phases=4, seed=7, in_run=True))
    static = compile_fleet(
        [dataclasses.replace(s, schedule=None) for s in scens])
    sched = compile_fleet(
        [dataclasses.replace(s,
                             schedule=LinkSchedule.constant(s.topo.n_links))
         for s in scens])

    def run_static():
        return simulate_many(static, policy, seconds=seconds, dt=DT)

    def run_sched():
        return simulate_many(sched, policy, seconds=seconds, dt=DT)

    run_static(), run_sched()  # compile both paths
    t_static, _ = _wall_median(run_static, WARM_REPS)
    t_sched, _ = _wall_median(run_sched, WARM_REPS)
    return [{
        "name": f"fleet_dynamics_{policy}",
        "us_per_call": t_sched * 1e6,
        "n_scenarios": len(sched),
        "backend": jax.default_backend(),
        "static_warm_s": round(t_static, 3),
        "scheduled_warm_s": round(t_sched, 3),
        "sched_overhead": round(t_sched / max(t_static, 1e-9), 2),
    }]


def run_reroute(policy: str = "appaware",
                seconds: float = SECONDS) -> list[dict]:
    """Mid-run rerouting machinery cost: the *identical* failure-scheduled
    scenarios once with capacity-only dynamics (the schedule degrades
    links, routes stay fixed) and once with the precompiled route bank
    (same schedule, plus the per-tick state stream and the in-scan
    ``route_bank`` gather). The workload difference is real — rerouted
    flows move different bytes — but the *machinery* being priced is the
    banked-gather path itself: the ratio must stay near 1, because the
    whole design point of precompiling ``[S_r, F, L]`` and streaming a
    per-tick int32 state index is that mid-run rerouting costs one gather,
    not a recompile or a ``lax.cond``."""
    import dataclasses

    scens = link_failure_sweep(n=8, seed=7, reroute=True)
    sched = compile_fleet(
        [dataclasses.replace(s, reroute=False) for s in scens])
    rer = compile_fleet(scens)
    assert all(s.is_rerouting for s in rer)

    def run_sched():
        return simulate_many(sched, policy, seconds=seconds, dt=DT)

    def run_rer():
        return simulate_many(rer, policy, seconds=seconds, dt=DT)

    run_sched(), run_rer()  # compile both paths
    # interleaved warm reps (see `run`): container drift cancels out of
    # the ratio instead of biasing it
    sched_ts, rer_ts = [], []
    for _ in range(WARM_REPS):
        t, _ = _wall(run_sched)
        sched_ts.append(t)
        t, _ = _wall(run_rer)
        rer_ts.append(t)
    t_sched = float(np.median(sched_ts))
    t_rer = float(np.median(rer_ts))
    n_states = max(int(np.asarray(s.route_bank).shape[0]) for s in rer)
    return [{
        "name": f"fleet_reroute_{policy}",
        "us_per_call": t_rer * 1e6,
        "n_scenarios": len(rer),
        "backend": jax.default_backend(),
        "sched_warm_s": round(t_sched, 3),
        "reroute_warm_s": round(t_rer, 3),
        # ~1: the banked gather is in-scan arithmetic, not a mode switch
        "reroute_overhead": round(t_rer / max(t_sched, 1e-9), 2),
        "max_route_states": n_states,
    }]


def run_campaign_bench(policy: str = "tcp", n: int = 256,
                       seconds: float = SECONDS,
                       chunk_rows: int = 64) -> list[dict]:
    """Streaming campaign vs materialized fleet on the same corpus.

    ``run_campaign`` pays per-chunk staging + dispatch + a [rows, 7]
    metric fetch; ``run`` pays one staged dispatch + full-trajectory
    transfer but amortizes staging across warm calls. The gate floor
    asserts streaming throughput ≥ 0.9× materialized — the bounded-memory
    mode must not cost more than the staging it re-does (the overlap with
    in-flight device compute is what pays for it; ``overlap_fraction``
    records how much staging wall-time was hidden). Warm reps are
    interleaved so container drift cancels out of the ratio (see `run`),
    and each side takes its best-of (min, à la timeit) — the run-to-run
    spread on a shared container is one-sided noise that a median over a
    handful of reps does not reject."""
    sims = compile_fleet(campaign_fleet(n, seed=0))
    runner = FleetRunner()

    def materialized():
        return runner.run(sims, policy, seconds=seconds, dt=DT)

    def streaming():
        return runner.run_campaign(sims, policy, seconds=seconds, dt=DT,
                                   chunk_rows=chunk_rows)

    materialized(), streaming()  # compile both paths
    mat_ts, str_ts, stats = [], [], None
    for _ in range(WARM_REPS):
        t, _ = _wall(materialized)
        mat_ts.append(t)
        t, _ = _wall(streaming)
        str_ts.append(t)
        stats = dict(runner.last_stats)
    t_mat = float(np.min(mat_ts))
    t_str = float(np.min(str_ts))
    cal = stats["calibration"]
    return [{
        "name": "fleet_campaign",
        "us_per_call": t_str * 1e6,
        "n_scenarios": n,
        "backend": jax.default_backend(),
        "materialized_warm_s": round(t_mat, 3),
        "streaming_warm_s": round(t_str, 3),
        # >= 1: streaming is at least as fast as materializing everything
        "stream_vs_materialized": round(t_mat / t_str, 2),
        "scenarios_per_s": round(n / t_str, 1),
        "chunk_rows": stats["chunk_rows"],
        "n_chunks": stats["n_chunks"],
        "n_streams": stats["n_streams"],
        "peak_staged_rows": stats["peak_staged_rows"],
        "peak_staged_bytes": stats["peak_staged_bytes"],
        "overlap_fraction": round(stats["overlap_fraction"], 3),
        # three-stage pipeline split: H2D copy time, how much of it the
        # dispatch thread re-paid as waiting, and the resulting overlap
        "transfer_s": round(stats["transfer_s"], 3),
        "transfer_wait_s": round(stats["transfer_wait_s"], 3),
        "transfer_overlap": round(stats["transfer_overlap"], 3),
        # backend calibration behind chunk_rows="auto"
        "calib_dispatch_us": round(cal["dispatch_us"], 2),
        "calib_sync_us": round(cal["sync_us"], 2),
        "calib_tick_overhead_flops": round(cal["tick_overhead_flops"], 0),
        "calib_proxy_mflops": round(cal["proxy_mflops"], 0),
        "calib_clamped": cal["clamped"],
    }]


def run_campaign_auto(policy: str = "tcp", n: int = 256,
                      seconds: float = SECONDS) -> list[dict]:
    """`chunk_rows="auto"` vs a measured chunk-size sweep.

    Streams the same corpus at a grid of fixed chunk sizes plus "auto",
    and reports where auto's pick lands against the measured optimum. On
    CPU the warm curve is a broad plateau (per-dispatch overhead is tens
    of µs against tens-of-ms chunks), so the gateable claim is membership
    in the plateau — auto within ``plateau_tol`` of the best measured
    point — not an exact argmin match on a noisy shared core."""
    sims = compile_fleet(campaign_fleet(n, seed=0))
    runner = FleetRunner()
    grid = [16, 32, 64, 128]
    reps = max(2, WARM_REPS - 2)

    def stream(rows):
        def call():
            return runner.run_campaign(sims, policy, seconds=seconds,
                                       dt=DT, chunk_rows=rows)
        call()  # compile
        t, _ = _wall_median(call, reps)
        return t

    sweep = {rows: stream(rows) for rows in grid}
    t_auto = stream("auto")
    stats = dict(runner.last_stats)
    best_rows = min(sweep, key=sweep.get)
    t_best = sweep[best_rows]
    return [{
        "name": "fleet_campaign_auto",
        "us_per_call": t_auto * 1e6,
        "n_scenarios": n,
        "backend": jax.default_backend(),
        "auto_target_rows": stats["target_chunk_rows"],
        "auto_warm_s": round(t_auto, 3),
        "sweep_warm_s": {str(k): round(v, 3) for k, v in sweep.items()},
        "sweep_best_rows": best_rows,
        "sweep_best_s": round(t_best, 3),
        # <= plateau tolerance: auto picked within the measured plateau
        "auto_vs_best": round(t_auto / t_best, 3),
    }]


def run_campaign_resilience(policy: str = "tcp", n: int = 256,
                            seconds: float = SECONDS,
                            chunk_rows: int = 64) -> list[dict]:
    """Fault-free overhead of the resilience guards.

    The guarded side runs the campaign at its defaults — finite-check on
    every [rows, n_metrics] slab, the transfer watchdog armed, plus a
    checkpoint append (slab write + fsync'd manifest line) per chunk into
    a fresh directory per rep (a reused directory would resume instead of
    measure). The bare side switches every guard off. Reps are
    INTERLEAVED so container drift cancels out of the ratio, best-of
    (min) per side; the gate ceiling asserts guarded ≤ 1.05× bare in full
    mode — the resilience layer must be effectively free when nothing
    fails, since it is always on by default."""
    import shutil
    import tempfile

    sims = compile_fleet(campaign_fleet(n, seed=0))
    runner = FleetRunner()
    tmp = tempfile.mkdtemp(prefix="bench_resilience_ckpt_")
    n_ck = [0]

    def guarded():
        n_ck[0] += 1
        return runner.run_campaign(
            sims, policy, seconds=seconds, dt=DT, chunk_rows=chunk_rows,
            checkpoint=os.path.join(tmp, f"ck{n_ck[0]}"))

    def bare():
        return runner.run_campaign(
            sims, policy, seconds=seconds, dt=DT, chunk_rows=chunk_rows,
            finite_check=False, transfer_timeout_s=None)

    try:
        g0, b0 = guarded(), bare()  # compile (shared executables)
        assert np.array_equal(g0.metrics, b0.metrics)  # guards are inert
        assert not g0.failures
        g_ts, b_ts, stats = [], [], None
        for _ in range(WARM_REPS):
            t, _ = _wall(guarded)
            g_ts.append(t)
            stats = dict(runner.last_stats)
            t, _ = _wall(bare)
            b_ts.append(t)
        t_g = float(np.min(g_ts))
        t_b = float(np.min(b_ts))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return [{
        "name": "fleet_campaign_resilience",
        "us_per_call": t_g * 1e6,
        "n_scenarios": n,
        "backend": jax.default_backend(),
        "guarded_warm_s": round(t_g, 3),
        "bare_warm_s": round(t_b, 3),
        # ~1: finite-check + checkpoint append + watchdog are free when
        # nothing fails (gate ceiling: <= 1.05 full mode)
        "guard_overhead": round(t_g / t_b, 3),
        "n_chunks": stats["n_chunks"],
        "n_quarantined": stats["n_quarantined"],
        "n_retries": stats["n_retries"],
    }]


def run_campaign_scaling(policy: str = "tcp", n: int = 256,
                         seconds: float = SECONDS) -> list[dict]:
    """Sharded chunk stream at 4 emulated devices vs 1 device.

    The 4-device half runs in a subprocess (device count is baked in at
    jax import). On this 1-core container 4 emulated devices share one
    core, so the gateable number is a *not-much-worse* bound — sharding
    must not serialize or duplicate work (wall within the floor of the
    1-device run), while real scaling is a wide-backend claim (ROADMAP
    item 2). Metrics parity at 4 devices is asserted bitwise in
    tests/test_multidevice.py; this row tracks the wall-clock."""
    import json as _json
    import subprocess

    sims = compile_fleet(campaign_fleet(n, seed=0))
    runner = FleetRunner()

    def stream():
        return runner.run_campaign(sims, policy, seconds=seconds, dt=DT,
                                   shard=False)
    stream()  # compile
    t_1dev, _ = _wall_median(stream, max(2, WARM_REPS - 2))

    child = (
        "import json,sys,time,numpy as np\n"
        "from repro.streams import campaign_fleet, compile_fleet\n"
        "from repro.streams.fleet import FleetRunner\n"
        "import jax\n"
        f"sims = compile_fleet(campaign_fleet({n}, seed=0))\n"
        "r = FleetRunner()\n"
        f"call = lambda: r.run_campaign(sims, {policy!r}, "
        f"seconds={seconds}, dt={DT})\n"
        "call()\n"
        "ts = []\n"
        f"for _ in range({max(2, WARM_REPS - 2)}):\n"
        "    t0 = time.time(); call(); ts.append(time.time() - t0)\n"
        "st = r.last_stats\n"
        "print('SCALING ' + json.dumps({\n"
        "    'warm_s': float(np.median(ts)),\n"
        "    'n_streams': st['n_streams'],\n"
        "    'n_devices': jax.local_device_count(),\n"
        "    'transfer_overlap': st['transfer_overlap'],\n"
        "    'overlap_fraction': st['overlap_fraction']}))\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", child], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"4-device scaling child failed:\n{out.stderr}")
    payload = _json.loads(
        next(line for line in out.stdout.splitlines()
             if line.startswith("SCALING ")).split(" ", 1)[1])
    t_4dev = float(payload["warm_s"])
    return [{
        "name": "fleet_campaign_scaling",
        "us_per_call": t_4dev * 1e6,
        "n_scenarios": n,
        "backend": jax.default_backend(),
        "host_cores": os.cpu_count(),
        "n_devices": payload["n_devices"],
        "n_streams_4dev": payload["n_streams"],
        "warm_1dev_s": round(t_1dev, 3),
        "warm_4dev_s": round(t_4dev, 3),
        # >= floor: emulated sharding on a shared core must stay within
        # a constant factor of the single-stream run (not serialize or
        # duplicate work); > 1 means real parallel win (multi-core)
        "scaling_efficiency_4dev": round(t_1dev / t_4dev, 3),
        "transfer_overlap_4dev": round(payload["transfer_overlap"], 3),
        "overlap_fraction_4dev": round(payload["overlap_fraction"], 3),
    }]


def main() -> None:
    rows = []
    for policy in ("tcp", "appaware"):
        rows += run(policy)
    rows += run_dispatch_floor()
    rows += run_dynamics("tcp")
    rows += run_reroute()
    rows += run_order_cache()
    rows += run_campaign_bench()
    rows += run_campaign_auto()
    rows += run_campaign_resilience()
    rows += run_campaign_scaling()
    emit(rows, "fleet")


if __name__ == "__main__":
    main()
