"""Fleet engine benchmark: batched `simulate_many` vs a sequential
`simulate` loop over the same scenarios.

The sequential loop pays one XLA compile per distinct [F, L, I] shape plus
per-scenario dispatch; the batched path compiles ONE vmapped scan and runs
the whole fleet in a single fused program. Reports end-to-end wall-clock
(first call, compile included — the realistic "run a study" cost) and
steady-state (second call) speedups.

On CPU the scenario axis is additionally sharded across forced XLA host
devices (one per core, up to 8), so the fleet runs genuinely in parallel —
set BEFORE jax initializes, hence the env fiddling above the imports.

    PYTHONPATH=src python benchmarks/fleet.py
"""
from __future__ import annotations

import os
import sys
import time

if "jax" not in sys.modules:  # too late to force devices otherwise
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={min(os.cpu_count() or 1, 8)}"
    )

import jax

from benchmarks.common import emit
from repro.streams import (
    compile_fleet,
    random_scenarios,
    seed_fleet,
    simulate,
    simulate_many,
)

SECONDS = 60.0
DT = 0.5
N_EXTRA_RANDOM = 16  # on top of the 24-scenario seed corpus


def _wall(fn):
    t0 = time.time()
    out = fn()
    return time.time() - t0, out


def run(policy: str = "appaware", seconds: float = SECONDS) -> list[dict]:
    sims = compile_fleet(
        seed_fleet(seed=0) + random_scenarios(N_EXTRA_RANDOM, seed=42))

    def sequential():
        return [simulate(s, policy, seconds=seconds, dt=DT) for s in sims]

    def batched():
        return simulate_many(sims, policy, seconds=seconds, dt=DT)

    # cold: includes compilation — what one pays for a fresh parameter study
    t_seq_cold, _ = _wall(sequential)
    t_bat_cold, _ = _wall(batched)
    # warm: compile caches hot, pure execution
    t_seq_warm, seq = _wall(sequential)
    t_bat_warm, bat = _wall(batched)

    # sanity: batched results match the sequential loop
    worst = max(
        abs(a.throughput_tps - b.throughput_tps)
        for a, b in zip(seq, bat)
    )

    return [{
        "name": f"fleet_{policy}",
        "us_per_call": t_bat_warm * 1e6,
        "n_scenarios": len(sims),
        "backend": jax.default_backend(),
        "seq_cold_s": round(t_seq_cold, 2),
        "batch_cold_s": round(t_bat_cold, 2),
        "speedup_cold": round(t_seq_cold / t_bat_cold, 2),
        "seq_warm_s": round(t_seq_warm, 2),
        "batch_warm_s": round(t_bat_warm, 2),
        "speedup_warm": round(t_seq_warm / t_bat_warm, 2),
        "max_tps_diff": f"{worst:.2e}",
    }]


def main() -> None:
    for policy in ("tcp", "appaware"):
        emit(run(policy), "fleet")


if __name__ == "__main__":
    main()
