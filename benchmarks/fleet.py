"""Fleet engine benchmark: batched `simulate_many` (shape-bucketed
`FleetRunner`) vs a sequential `simulate` loop over the same scenarios.

The sequential loop pays one XLA compile per distinct [F, L, I] shape plus
per-scenario dispatch; the bucketed path compiles one vmapped scan per
shape bucket and runs each bucket as a single fused program. Reports
end-to-end wall-clock for the cold path (first call, compiles included —
the realistic "run a fresh study" cost) and the steady-state warm path.
Warm timings are the **median of WARM_REPS repeat calls**: post-compile
calls are tens of milliseconds, where single-shot wall-clock on a shared
CI core is noise-dominated.

On CPU the scenario axis is additionally split across forced XLA host
devices (one per core, up to 8) via the runner's plain-SPMD sharding —
set BEFORE jax initializes, hence the env fiddling above the imports.

    PYTHONPATH=src python benchmarks/fleet.py
"""
from __future__ import annotations

import os
import sys
import time

if "jax" not in sys.modules:  # too late to force devices otherwise
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={min(os.cpu_count() or 1, 8)}"
    )

import jax
import numpy as np

from benchmarks.common import emit
from repro.streams import (
    compile_fleet,
    link_failure_sweep,
    random_scenarios,
    seed_fleet,
    simulate,
    simulate_many,
    time_varying_sweep,
)

SECONDS = 60.0
DT = 0.5
N_EXTRA_RANDOM = 16  # on top of the 24-scenario seed corpus
WARM_REPS = 5


def _wall(fn):
    t0 = time.time()
    out = fn()
    return time.time() - t0, out


def _wall_median(fn, reps: int):
    ts, out = [], None
    for _ in range(reps):
        t, out = _wall(fn)
        ts.append(t)
    return float(np.median(ts)), out


def run(policy: str = "appaware", seconds: float = SECONDS) -> list[dict]:
    sims = compile_fleet(
        seed_fleet(seed=0) + random_scenarios(N_EXTRA_RANDOM, seed=42))

    def sequential():
        return [simulate(s, policy, seconds=seconds, dt=DT) for s in sims]

    def batched():
        return simulate_many(sims, policy, seconds=seconds, dt=DT)

    # cold: includes compilation — what one pays for a fresh parameter study
    t_seq_cold, _ = _wall(sequential)
    t_bat_cold, _ = _wall(batched)
    # warm: compile caches hot, pure execution (median over repeat calls)
    t_seq_warm, seq = _wall_median(sequential, WARM_REPS)
    t_bat_warm, bat = _wall_median(batched, WARM_REPS)

    # sanity: batched results match the sequential loop
    worst = max(
        abs(a.throughput_tps - b.throughput_tps)
        for a, b in zip(seq, bat)
    )

    return [{
        "name": f"fleet_{policy}",
        "us_per_call": t_bat_warm * 1e6,
        "n_scenarios": len(sims),
        "backend": jax.default_backend(),
        "seq_cold_s": round(t_seq_cold, 2),
        "batch_cold_s": round(t_bat_cold, 2),
        "speedup_cold": round(t_seq_cold / t_bat_cold, 2),
        "seq_warm_s": round(t_seq_warm, 3),
        "batch_warm_s": round(t_bat_warm, 3),
        "speedup_warm": round(t_seq_warm / t_bat_warm, 2),
        "max_tps_diff": f"{worst:.2e}",
    }]


def run_dynamics(policy: str = "tcp", seconds: float = SECONDS) -> list[dict]:
    """Scheduled-caps machinery cost vs static: the *identical* scenarios
    once with no schedule and once with a constant (no-op) schedule. A
    constant schedule produces bitwise-identical trajectories but takes
    the full dynamic path — [T, L] capacity stream into the scan plus
    per-tick enforcement — so the ratio isolates exactly what in-run
    dynamics cost, with zero workload difference (a real failure schedule
    would also change queue dynamics and the max-min solver's
    data-dependent trip counts, conflating workload with machinery)."""
    import dataclasses

    from repro.net import LinkSchedule

    scens = (link_failure_sweep(n=4, seed=7, in_run=True)
             + time_varying_sweep(n_phases=4, seed=7, in_run=True))
    static = compile_fleet(
        [dataclasses.replace(s, schedule=None) for s in scens])
    sched = compile_fleet(
        [dataclasses.replace(s,
                             schedule=LinkSchedule.constant(s.topo.n_links))
         for s in scens])

    def run_static():
        return simulate_many(static, policy, seconds=seconds, dt=DT)

    def run_sched():
        return simulate_many(sched, policy, seconds=seconds, dt=DT)

    run_static(), run_sched()  # compile both paths
    t_static, _ = _wall_median(run_static, WARM_REPS)
    t_sched, _ = _wall_median(run_sched, WARM_REPS)
    return [{
        "name": f"fleet_dynamics_{policy}",
        "us_per_call": t_sched * 1e6,
        "n_scenarios": len(sched),
        "backend": jax.default_backend(),
        "static_warm_s": round(t_static, 3),
        "scheduled_warm_s": round(t_sched, 3),
        "sched_overhead": round(t_sched / max(t_static, 1e-9), 2),
    }]


def main() -> None:
    for policy in ("tcp", "appaware"):
        emit(run(policy), "fleet")
    emit(run_dynamics("tcp"), "fleet")


if __name__ == "__main__":
    main()
