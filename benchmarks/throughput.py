"""Fig. 8 & Fig. 9: application throughput, TCP vs App-aware, at
10/15/20 Mbps — single-hop (up/downlink) and multi-hop (fat-tree internal)
bottlenecks. Paper: App-aware +15–31% (single-hop), +15–24% (multi-hop)."""
from __future__ import annotations

from benchmarks.common import (
    CAPS,
    emit,
    multihop_topo,
    run_pair,
    singlehop_topo,
)
from repro.streams import trending_topics, trucking_iot


def run(figure: str = "fig8") -> list[dict]:
    topo_fn = singlehop_topo if figure == "fig8" else multihop_topo
    rows = []
    for app_name, app_fn in (("TT", trending_topics), ("TI", trucking_iot)):
        for cap_name, cap in CAPS.items():
            tcp, aa = run_pair(app_fn, topo_fn(cap))
            imp = (aa.throughput_tps / max(tcp.throughput_tps, 1e-9) - 1) * 100
            rows.append({
                "name": f"{figure}_throughput_{app_name}_{cap_name}",
                "tcp_tps": round(tcp.throughput_tps, 1),
                "appaware_tps": round(aa.throughput_tps, 1),
                "improvement_pct": round(imp, 1),
            })
    return rows


def main() -> None:
    for fig in ("fig8", "fig9"):
        emit(run(fig), fig)


if __name__ == "__main__":
    main()
