"""Roofline terms per (arch × shape × mesh) from the dry-run artifacts
(deliverable g). Reads results/dryrun/*.json; prints one row per cell."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.launch.roofline import cell_terms

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def terms(rec: dict) -> dict:
    t = cell_terms(rec)
    return {
        "compute_s": t["t_c"], "memory_floor_s": t["t_mf"],
        "memory_hlo_s": t["t_m"], "collective_s": t["t_n"],
        "dominant": t["dominant"], "model_flops": t["model_flops"],
        "useful_ratio": t["ratio"],
        "roofline_fraction": t["frac"],
        "step_lower_bound_s": t["bound"],
    }


def run() -> list[dict]:
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            rows.append({"name": f"roofline_{f.stem}",
                         "error": rec.get("error", "?")[:80]})
            continue
        t = terms(rec)
        rows.append({
            "name": f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}",
            "us_per_call": t["step_lower_bound_s"] * 1e6,
            "compute_s": f"{t['compute_s']:.4f}",
            "memory_floor_s": f"{t['memory_floor_s']:.4f}",
            "memory_hlo_s": f"{t['memory_hlo_s']:.4f}",
            "collective_s": f"{t['collective_s']:.4f}",
            "dominant": t["dominant"],
            "useful_ratio": f"{t['useful_ratio']:.3f}",
            "roofline_fraction": f"{t['roofline_fraction']:.3f}",
        })
    return rows


def main() -> None:
    emit(run(), "roofline")


if __name__ == "__main__":
    main()
