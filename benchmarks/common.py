"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import numpy as np

from repro.net import LinkKind, big_switch, fat_tree
from repro.streams import (
    compile_sim,
    parallelize,
    round_robin,
    simulate,
    trending_topics,
    trucking_iot,
)

CAPS = {"10Mbps": 1.25, "15Mbps": 1.875, "20Mbps": 2.5}
SECONDS = 600.0
DT = 0.5


def run_pair(app_fn, topo, seconds=SECONDS, seed=0, **sim_kw):
    """Run TCP vs App-aware on one app/topology; returns (tcp, appaware)."""
    g = parallelize(app_fn(), seed=seed)
    sim = compile_sim(g, topo, round_robin(g, topo.n_machines))
    tcp = simulate(sim, "tcp", seconds=seconds, dt=DT, **sim_kw)
    aa = simulate(sim, "appaware", seconds=seconds, dt=DT, **sim_kw)
    return tcp, aa


def singlehop_topo(cap: float):
    """10-machine cluster, 8 workers, bottleneck at machine up/downlinks."""
    return big_switch(8, cap)


def multihop_topo(cap: float):
    """Fat-tree testbed (Fig. 2) with throttled internal links (§VI-A.1)."""
    return fat_tree(up=12.5).set_capacity(LinkKind.INTERNAL, cap)


def emit(rows: list[dict], name: str) -> None:
    """CSV to stdout: name,us_per_call,derived-metrics..."""
    for r in rows:
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{r.get('name', name)},{r.get('us_per_call', 0):.2f},{derived}")


def timeit_us(fn, iters: int = 10) -> float:
    fn()  # compile
    t0 = time.time()
    for _ in range(iters):
        fn()
    return (time.time() - t0) / iters * 1e6
