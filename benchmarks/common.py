"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time


from repro.net import LinkKind, big_switch, fat_tree
from repro.streams import compile_sim, parallelize, round_robin, simulate

CAPS = {"10Mbps": 1.25, "15Mbps": 1.875, "20Mbps": 2.5}
SECONDS = 600.0
DT = 0.5


def run_pair(app_fn, topo, seconds=SECONDS, seed=0, **sim_kw):
    """Run TCP vs App-aware on one app/topology; returns (tcp, appaware)."""
    g = parallelize(app_fn(), seed=seed)
    sim = compile_sim(g, topo, round_robin(g, topo.n_machines))
    tcp = simulate(sim, "tcp", seconds=seconds, dt=DT, **sim_kw)
    aa = simulate(sim, "appaware", seconds=seconds, dt=DT, **sim_kw)
    return tcp, aa


def singlehop_topo(cap: float):
    """10-machine cluster, 8 workers, bottleneck at machine up/downlinks."""
    return big_switch(8, cap)


def multihop_topo(cap: float):
    """Fat-tree testbed (Fig. 2) with throttled internal links (§VI-A.1)."""
    return fat_tree(up=12.5).set_capacity(LinkKind.INTERNAL, cap)


def smoke_mode() -> bool:
    """True when REPRO_SMOKE is set (the CI runner): benchmarks shrink
    their problem sizes / iteration counts, and perf_gate applies its
    conservative smoke floors. One definition so a bench and the gate
    can never disagree about which mode a run was in."""
    return os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0")


_JSON_ROWS: dict[str, list[dict]] = {}


# repo root: BENCH_*.json always lands here (full *and* smoke mode, any
# CWD) so the per-PR perf trajectory is never silently empty; override
# with BENCH_DIR for scratch runs
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def emit(rows: list[dict], name: str) -> None:
    """CSV to stdout: name,us_per_call,derived-metrics...

    Every section also accumulates into ``BENCH_<name>.json`` (in
    ``BENCH_DIR``, default the repo root) so CI can upload the per-PR perf
    trajectory as a workflow artifact.

    ``us_per_call`` is *optional* — rows that carry no timing (pure
    invariant/observable rows like ``fleet_order_cache``) simply omit the
    field and print ``-`` in its column. A row that DOES carry it must
    carry a real measurement: zero or negative timings are rejected here
    so a broken timer can't silently land as a plausible-looking 0.0 in
    the committed JSON again."""
    for r in rows:
        us = r.get("us_per_call")
        if us is not None and not float(us) > 0.0:
            raise ValueError(
                f"row {r.get('name', name)!r}: us_per_call={us!r} is not a "
                f"positive timing — omit the field for non-timing rows")
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        col = f"{float(us):.2f}" if us is not None else "-"
        print(f"{r.get('name', name)},{col},{derived}")
    _JSON_ROWS.setdefault(name, []).extend(rows)
    path = os.path.join(os.environ.get("BENCH_DIR", _REPO_ROOT),
                        f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(_JSON_ROWS[name], f, indent=1, default=str)


def timeit_us(fn, iters: int = 10) -> float:
    fn()  # compile
    t0 = time.time()
    for _ in range(iters):
        fn()
    return (time.time() - t0) / iters * 1e6
