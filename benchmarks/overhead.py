"""§VI-D: optimizer/enforcer overhead. Paper: ~6 ms per allocation on their
testbed scale; controller→switch updates 0.1–10 ms. We time (a) the full
Alg. 1 allocation on the paper-scale problem, (b) the batched Pallas
waterfill at datacenter scale (10⁴ links full mode; shrunk under
REPRO_SMOKE so the CI leg finishes in seconds — the row records which),
(c) the TCP max-min baseline, and (d) the campaign runtime's backend
calibration (dispatch/sync/tick overhead — the measurements behind
``chunk_rows="auto"``), emitted to ``BENCH_overhead.json`` like every
other bench so CI uploads the trajectory and ``perf_gate`` can demand the
snapshot exists.

    PYTHONPATH=src:. python benchmarks/overhead.py
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, smoke_mode, timeit_us
from repro.core import FlowState, OnlineAllocator, maxmin_rates
from repro.kernels.waterfill.ops import waterfill
from repro.net import fat_tree
from repro.streams import parallelize, round_robin, trending_topics
from repro.streams.fleet import calibrate_backend


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    smoke = smoke_mode()

    # (a) paper-scale: TT app on the fat-tree testbed
    g = parallelize(trending_topics(), seed=0)
    topo = fat_tree()
    flows = g.flow_pairs(round_robin(g, topo.n_machines))
    alloc = OnlineAllocator.from_topology(topo, flows)
    F = len(flows)
    st = FlowState(*[jnp.asarray(rng.uniform(0, 10, F), jnp.float32)
                     for _ in range(5)])
    us = timeit_us(lambda: jax.block_until_ready(alloc(st)),
                   iters=3 if smoke else 10)
    rows.append({"name": "overhead_alg1_paper_scale", "us_per_call": us,
                 "flows": F, "links": topo.n_links,
                 "paper_ms": 6.0, "ours_ms": round(us / 1e3, 3)})

    # (b) datacenter scale, Pallas kernel: 8192 links x 256 flows in full
    # mode; smoke shrinks the grid so the interpret-mode CPU run fits a
    # CI leg (the mode is recorded — the two scales are not comparable)
    L, Fk = (512, 64) if smoke else (8192, 256)
    w = jnp.asarray(rng.uniform(0, 20, (L, Fk)), jnp.float32)
    bl = jnp.asarray(rng.uniform(0, 30, (L, Fk)), jnp.float32)
    rho = jnp.asarray(rng.uniform(0.1, 10, (L, Fk)), jnp.float32)
    mask = jnp.asarray(rng.random((L, Fk)) < 0.5, jnp.float32)
    cap = jnp.asarray(rng.uniform(1, 50, L), jnp.float32)
    kind = jnp.asarray(rng.integers(0, 2, L), jnp.int32)
    us = timeit_us(
        lambda: jax.block_until_ready(
            waterfill(w, bl, rho, mask, cap, kind)),
        iters=2 if smoke else 3)
    rows.append({"name": f"overhead_waterfill_kernel_{L}x{Fk}",
                 "us_per_call": us,
                 "links": L, "flows_per_link": Fk,
                 "smoke": smoke,
                 "note": "interpret-mode on CPU; TPU compiled is the target"})

    # (c) TCP max-min on the same paper-scale problem
    R = jnp.asarray(topo.routing_matrix(flows), jnp.float32)
    caps = jnp.asarray(topo.capacities, jnp.float32)
    us = timeit_us(lambda: jax.block_until_ready(maxmin_rates(R, caps)),
                   iters=3 if smoke else 10)
    rows.append({"name": "overhead_tcp_maxmin", "us_per_call": us})

    # (d) campaign backend calibration: per-dispatch / sync / per-tick
    # overhead as measured by the `chunk_rows="auto"` machinery — the
    # same numbers run_campaign records in last_stats["calibration"]
    cal = calibrate_backend()
    rows.append({"name": "overhead_backend_calibration",
                 "us_per_call": cal.dispatch_us,
                 **dataclasses.asdict(cal)})
    return rows


def main() -> None:
    emit(run(), "overhead")


if __name__ == "__main__":
    main()
