"""§VI-D: optimizer/enforcer overhead. Paper: ~6 ms per allocation on their
testbed scale; controller→switch updates 0.1–10 ms. We time (a) the full
Alg. 1 allocation on the paper-scale problem, (b) the batched Pallas
waterfill at datacenter scale (10⁴ links), (c) the TCP max-min baseline."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit_us
from repro.core import FlowState, OnlineAllocator, maxmin_rates
from repro.kernels.waterfill.ops import waterfill
from repro.net import fat_tree
from repro.streams import parallelize, round_robin, trending_topics


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # (a) paper-scale: TT app on the fat-tree testbed
    g = parallelize(trending_topics(), seed=0)
    topo = fat_tree()
    flows = g.flow_pairs(round_robin(g, topo.n_machines))
    alloc = OnlineAllocator.from_topology(topo, flows)
    F = len(flows)
    st = FlowState(*[jnp.asarray(rng.uniform(0, 10, F), jnp.float32)
                     for _ in range(5)])
    us = timeit_us(lambda: jax.block_until_ready(alloc(st)))
    rows.append({"name": "overhead_alg1_paper_scale", "us_per_call": us,
                 "flows": F, "links": topo.n_links,
                 "paper_ms": 6.0, "ours_ms": round(us / 1e3, 3)})

    # (b) datacenter scale: 8192 links × 256 flows each, Pallas kernel
    L, Fk = 8192, 256
    w = jnp.asarray(rng.uniform(0, 20, (L, Fk)), jnp.float32)
    bl = jnp.asarray(rng.uniform(0, 30, (L, Fk)), jnp.float32)
    rho = jnp.asarray(rng.uniform(0.1, 10, (L, Fk)), jnp.float32)
    mask = jnp.asarray(rng.random((L, Fk)) < 0.5, jnp.float32)
    cap = jnp.asarray(rng.uniform(1, 50, L), jnp.float32)
    kind = jnp.asarray(rng.integers(0, 2, L), jnp.int32)
    us = timeit_us(
        lambda: jax.block_until_ready(
            waterfill(w, bl, rho, mask, cap, kind)), iters=3)
    rows.append({"name": "overhead_waterfill_kernel_8192x256",
                 "us_per_call": us,
                 "links": L, "flows_per_link": Fk,
                 "note": "interpret-mode on CPU; TPU compiled is the target"})

    # (c) TCP max-min on the same paper-scale problem
    R = jnp.asarray(topo.routing_matrix(flows), jnp.float32)
    caps = jnp.asarray(topo.capacities, jnp.float32)
    us = timeit_us(lambda: jax.block_until_ready(maxmin_rates(R, caps)))
    rows.append({"name": "overhead_tcp_maxmin", "us_per_call": us})
    return rows


def main() -> None:
    emit(run(), "overhead")


if __name__ == "__main__":
    main()
