"""Benchmark runner — one section per paper table/figure plus the roofline
table from the dry-run. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        fairness,
        latency,
        motivation,
        overhead,
        roofline,
        throughput,
        utilization,
    )

    sections = [
        ("fig3", motivation.main),
        ("fig8+9", throughput.main),
        ("fig10+11", latency.main),
        ("fig12", utilization.main),
        ("fig13", fairness.main),
        ("overhead", overhead.main),
        ("roofline", roofline.main),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    for name, fn in sections:
        if only and only not in name:
            continue
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
