"""Fig. 10 & Fig. 11: average end-to-end tuple latency, TCP vs App-aware.
Paper: App-aware −14–50% (TT single-hop), −6–17% (TI); multi-hop TI ≈ parity
(heavily congested internals)."""
from __future__ import annotations

from benchmarks.common import (
    CAPS,
    emit,
    multihop_topo,
    run_pair,
    singlehop_topo,
)
from repro.streams import trending_topics, trucking_iot


def run(figure: str = "fig10") -> list[dict]:
    topo_fn = singlehop_topo if figure == "fig10" else multihop_topo
    rows = []
    for app_name, app_fn in (("TT", trending_topics), ("TI", trucking_iot)):
        for cap_name, cap in CAPS.items():
            tcp, aa = run_pair(app_fn, topo_fn(cap))
            imp = (1 - aa.avg_latency_s / max(tcp.avg_latency_s, 1e-9)) * 100
            rows.append({
                "name": f"{figure}_latency_{app_name}_{cap_name}",
                "tcp_latency_s": round(tcp.avg_latency_s, 2),
                "appaware_latency_s": round(aa.avg_latency_s, 2),
                "improvement_pct": round(imp, 1),
            })
    return rows


def main() -> None:
    for fig in ("fig10", "fig11"):
        emit(run(fig), fig)


if __name__ == "__main__":
    main()
