"""CI perf gate: fail the job when the fleet warm path regresses.

Parses ``BENCH_fleet.json`` (written by ``benchmarks/fleet.py``) and
checks, per policy:

* ``speedup_warm`` against a checked-in floor,
* ``n_dispatches == 1`` — the packed runtime's structural invariant: a
  warm fleet run is ONE fused executable. A solver or runner change that
  silently falls back to per-bucket dispatch fails the gate even if the
  wall-clock happens to look fine on the runner that day, and
* the ``fleet_order_cache`` row — the order-cached max-min solver must
  rebuild its demand-rank operand exactly ONCE per scenario on the
  static-demand corpus scan (the tick-0 cold start). More than one means
  the O(F) order check is spuriously invalidating carried state (the
  order cache silently degrades to rebuild-every-tick); zero means the
  cold start stopped being counted, and
* the ``fleet_campaign`` row — the streaming campaign mode
  (``FleetRunner.run_campaign``) must keep its throughput within the
  floor of the materialized path on the same corpus
  (``stream_vs_materialized``: chunk staging re-done per call has to be
  paid for by its overlap with in-flight device compute), its host
  staging bounded (``peak_staged_rows`` ≤ 3 × ``chunk_rows`` ×
  ``n_streams`` — the three rotating slots per device stream, one per
  pipeline stage; more means the bounded-memory property silently broke
  and a 10⁴-scenario campaign would materialize after all), and its H2D
  prefetch overlapped (``transfer_overlap`` above the floor — 0 means
  the dispatch thread re-paid every copy, i.e. the transfer worker
  stopped prefetching), and
* the ``fleet_campaign_resilience`` row — the always-on fault-tolerance
  guards (finite-check per metric slab, transfer watchdog, checkpoint
  append) must be effectively free on the fault-free path
  (``guard_overhead`` ≤ the ceiling; and the fault-free A/B must report
  zero retries/quarantines — anything else means the guards misfire
  without faults), and
* the ``fleet_campaign_scaling`` row — the 4-emulated-device sharded
  chunk stream must stay within a constant factor of the 1-device run
  (``scaling_efficiency_4dev``; on the 1-core CI container the four
  streams share one core, so the floor only catches sharding that
  serializes or duplicates work — real scaling is the wide-backend
  ROADMAP item).

Missing input files are a hard, *loud* failure: benchmark snapshots are
checked into the repo (see ``.gitignore`` history — they used to be
ignored, which made "the gate passed" indistinguishable from "the gate
read nothing"), so an absent ``BENCH_*.json`` means the bench step was
skipped or its artifact lost, and the gate says exactly that instead of
raising a bare traceback.

On failure (and success) the gate prints the full measured-vs-floor table,
so a red CI job shows every margin at a glance instead of a bare assert.

Two modes:

* **smoke** (``REPRO_SMOKE=1``, the CI runner): floors are deliberately
  conservative — the shared CI runner's wall-clock is noisy and the
  sequential baseline there is itself fast, so the gate only catches real
  regressions (e.g. a change that re-serializes the batch), not
  scheduling jitter.
* **full** (REPRO_SMOKE unset): asserts the ROADMAP target for the
  measured-and-re-scoped warm-path item.

    PYTHONPATH=src:. python benchmarks/perf_gate.py [path/to/BENCH_fleet.json]
"""
from __future__ import annotations

import json
import os
import sys

# speedup_warm is strongly container-class dependent: the quiet 2-core
# container of PR 5 measured tcp 2.43 / appaware 2.67, while the loaded
# 1-core container that produced the committed BENCH_fleet.json measures
# 1.16 / 1.16 for the SAME code — op-dispatch contention slows the
# batched and sequential sides almost equally, so the ratio compresses
# toward 1 long before anything is actually wrong (interleaved A/B
# old-vs-new solver on that container: neutral on both sides, see
# ROADMAP item 1). Floors are therefore set to catch structural
# regressions — a batch path that re-serializes drops to <= 1.0 on ANY
# container — not to re-assert the quiet-container headline, which only
# the quiet-container BENCH refresh can do.
SMOKE_FLOORS = {"fleet_tcp": 1.05, "fleet_appaware": 1.05}
# Full-mode floors: a guard band under the weakest container class we
# have measured (1.16/1.16, loaded 1-core).
FULL_FLOORS = {"fleet_tcp": 1.1, "fleet_appaware": 1.1}

# Streaming-vs-materialized throughput floors (ratio of warm wall-clocks,
# same corpus, interleaved reps): ISSUE-7 target is >= 0.9x in full mode;
# smoke keeps a wider band for the noisy shared CI runner.
CAMPAIGN_SMOKE_FLOOR = 0.8
CAMPAIGN_FULL_FLOOR = 0.9

# H2D prefetch overlap floors: the fraction of copy time the dispatch
# thread did NOT re-pay as waiting. The loaded 1-core container measures
# ~0.5-0.9 depending on chunk compute; the floor only asserts the
# transfer worker still prefetches at all (0 = every copy waited on).
TRANSFER_OVERLAP_SMOKE_FLOOR = 0.05
TRANSFER_OVERLAP_FULL_FLOOR = 0.2

# Mid-run rerouting machinery ceilings (t_reroute / t_sched on the same
# failure-scheduled corpus): the precompiled route bank turns mid-run
# rerouting into one in-scan gather, so the warm ratio sits at ~1.0
# (measured 0.95 on the loaded 1-core container). The ceiling catches a
# change that reintroduces a per-state recompile or a lax.cond mode
# switch — either shows up as a multiple, not a few percent.
REROUTE_SMOKE_CEIL = 2.0
REROUTE_FULL_CEIL = 1.5

# Resilience guard ceilings (t_guarded / t_bare, interleaved best-of on
# the same corpus): the always-on fault-tolerance layer — finite-check on
# every metric slab, the transfer watchdog, a checkpoint append per chunk
# — must be effectively free on the fault-free path. ISSUE-10 target is
# <= 1.05x in full mode; smoke keeps a wider band because the fsync'd
# checkpoint appends meet a noisy shared-runner filesystem.
RESILIENCE_SMOKE_CEIL = 1.25
RESILIENCE_FULL_CEIL = 1.05

# 4-emulated-device scaling floors (t_1dev / t_4dev): on a 1-core
# container the four streams share the core, so anything >= ~0.6 means
# the shard neither serialized nor duplicated work; multi-core targets
# (> 1) belong to the wide-backend ROADMAP item, not this gate.
SCALING_SMOKE_FLOOR = 0.5
SCALING_FULL_FLOOR = 0.6

# Companion snapshots that must exist alongside the gate's own input —
# their absence means the bench job silently skipped a section.
COMPANION_FILES = ("BENCH_allocator.json", "BENCH_overhead.json")


def _load(path: str):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def check(path: str) -> int:
    rows = _load(path)
    if rows is None:
        print(f"perf gate FAILED:\n  {path}: benchmark snapshot missing — "
              f"run `PYTHONPATH=src:. python benchmarks/fleet.py` (or "
              f"restore the committed BENCH_fleet.json); a missing input "
              f"is a gate failure, never a silent pass")
        return 1
    try:
        # one mode definition shared with the benches (common.smoke_mode);
        # falls back to the same env check when run without PYTHONPATH=src
        # (benchmarks.common imports repro at module level)
        from benchmarks.common import smoke_mode
        smoke = smoke_mode()
    except ImportError:
        smoke = os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0")
    floors = SMOKE_FLOORS if smoke else FULL_FLOORS
    by_name = {r.get("name"): r for r in rows}
    table, failures = [], []
    for name, floor in floors.items():
        row = by_name.get(name)
        if row is None:
            failures.append(f"{name}: missing from {path}")
            table.append((name, "missing", f"{floor:.2f}", "-", "MISSING"))
            continue
        got = float(row.get("speedup_warm", 0.0))
        disp = row.get("n_dispatches")
        ok_speed = got >= floor
        ok_disp = disp == 1
        status = "ok" if (ok_speed and ok_disp) else "REGRESSED"
        table.append((name, f"{got:.2f}", f"{floor:.2f}",
                      f"{disp}", status))
        if not ok_speed:
            failures.append(
                f"{name}: speedup_warm {got:.2f} < floor {floor:.2f}")
        if not ok_disp:
            failures.append(
                f"{name}: n_dispatches {disp} != 1 (packed runtime "
                f"fell back to per-bucket dispatch)")
    # order-cache structural invariant: exactly one rebuild per scenario
    # on the static-demand corpus scan
    oc = by_name.get("fleet_order_cache")
    if oc is None:
        failures.append(f"fleet_order_cache: missing from {path}")
        table.append(("fleet_order_cache", "missing", "1/scenario", "-",
                      "MISSING"))
    else:
        lo = int(oc.get("static_demand_rebuilds_min", -1))
        hi = int(oc.get("static_demand_rebuilds_max", -1))
        ok = lo == 1 and hi == 1
        table.append(("fleet_order_cache", f"rebuilds {lo}..{hi}",
                      "1/scenario", "-", "ok" if ok else "REGRESSED"))
        if not ok:
            failures.append(
                f"fleet_order_cache: static-demand rebuilds per scenario "
                f"in [{lo}, {hi}], expected exactly 1 (order cache "
                f"{'over-invalidates' if hi > 1 else 'lost its cold-start count'})")
    # streaming campaign mode: throughput floor + bounded host staging +
    # H2D prefetch overlap
    cp = by_name.get("fleet_campaign")
    cfloor = CAMPAIGN_SMOKE_FLOOR if smoke else CAMPAIGN_FULL_FLOOR
    tfloor = (TRANSFER_OVERLAP_SMOKE_FLOOR if smoke
              else TRANSFER_OVERLAP_FULL_FLOOR)
    if cp is None:
        failures.append(f"fleet_campaign: missing from {path}")
        table.append(("fleet_campaign", "missing", f"{cfloor:.2f}", "-",
                      "MISSING"))
    else:
        ratio = float(cp.get("stream_vs_materialized", 0.0))
        peak = int(cp.get("peak_staged_rows", -1))
        crows = int(cp.get("chunk_rows", 0))
        streams = max(int(cp.get("n_streams", 1)), 1)
        tover = float(cp.get("transfer_overlap", -1.0))
        bound = 3 * crows * streams
        ok_ratio = ratio >= cfloor
        ok_peak = 0 <= peak <= bound
        ok_tover = tover >= tfloor
        status = ("ok" if (ok_ratio and ok_peak and ok_tover)
                  else "REGRESSED")
        table.append(("fleet_campaign", f"{ratio:.2f}", f"{cfloor:.2f}",
                      f"peak {peak}/{bound}", status))
        if not ok_ratio:
            failures.append(
                f"fleet_campaign: stream_vs_materialized {ratio:.2f} < "
                f"floor {cfloor:.2f} (streaming mode lost its overlap)")
        if not ok_peak:
            failures.append(
                f"fleet_campaign: peak_staged_rows {peak} > 3 x chunk_rows "
                f"{crows} x n_streams {streams} — host staging is no "
                f"longer bounded by the per-stream rotating slots")
        if not ok_tover:
            failures.append(
                f"fleet_campaign: transfer_overlap {tover:.2f} < floor "
                f"{tfloor:.2f} (H2D prefetch no longer overlaps — the "
                f"dispatch thread re-pays every copy)")
    # mid-run rerouting: the banked in-scan gather must stay cheap
    rr = by_name.get("fleet_reroute_appaware")
    rceil = REROUTE_SMOKE_CEIL if smoke else REROUTE_FULL_CEIL
    if rr is None:
        failures.append(f"fleet_reroute_appaware: missing from {path}")
        table.append(("fleet_reroute_appaware", "missing",
                      f"<= {rceil:.2f}", "-", "MISSING"))
    else:
        over = float(rr.get("reroute_overhead", float("inf")))
        ok = over <= rceil
        table.append(("fleet_reroute_appaware", f"{over:.2f}",
                      f"<= {rceil:.2f}",
                      f"{rr.get('max_route_states')} states",
                      "ok" if ok else "REGRESSED"))
        if not ok:
            failures.append(
                f"fleet_reroute_appaware: reroute_overhead {over:.2f} > "
                f"ceiling {rceil:.2f} — the route bank stopped being a "
                f"cheap in-scan gather (per-state recompile or cond "
                f"mode switch reintroduced)")
    # resilience guards free when nothing fails: guarded/bare <= ceiling,
    # and the fault-free A/B must have quarantined or retried nothing
    rs = by_name.get("fleet_campaign_resilience")
    gceil = RESILIENCE_SMOKE_CEIL if smoke else RESILIENCE_FULL_CEIL
    if rs is None:
        failures.append(f"fleet_campaign_resilience: missing from {path}")
        table.append(("fleet_campaign_resilience", "missing",
                      f"{gceil:.2f}", "-", "MISSING"))
    else:
        over = float(rs.get("guard_overhead", float("inf")))
        clean = (rs.get("n_quarantined") == 0 and rs.get("n_retries") == 0)
        status = "ok" if (over <= gceil and clean) else "REGRESSED"
        table.append(("fleet_campaign_resilience", f"{over:.2f}",
                      f"<= {gceil:.2f}", "-", status))
        if over > gceil:
            failures.append(
                f"fleet_campaign_resilience: guard_overhead {over:.2f} > "
                f"ceiling {gceil:.2f} — the fault-free path is paying for "
                f"the resilience layer")
        if not clean:
            failures.append(
                f"fleet_campaign_resilience: fault-free A/B reported "
                f"retries/quarantines "
                f"({rs.get('n_retries')}/{rs.get('n_quarantined')}) — the "
                f"guards are misfiring without faults")
    # sharded chunk stream at 4 emulated devices: within a constant
    # factor of the 1-device run
    sc = by_name.get("fleet_campaign_scaling")
    sfloor = SCALING_SMOKE_FLOOR if smoke else SCALING_FULL_FLOOR
    if sc is None:
        failures.append(f"fleet_campaign_scaling: missing from {path}")
        table.append(("fleet_campaign_scaling", "missing", f"{sfloor:.2f}",
                      "-", "MISSING"))
    else:
        eff = float(sc.get("scaling_efficiency_4dev", 0.0))
        ndev = sc.get("n_devices")
        ok_eff = eff >= sfloor
        ok_dev = ndev == 4
        status = "ok" if (ok_eff and ok_dev) else "REGRESSED"
        table.append(("fleet_campaign_scaling", f"{eff:.2f}",
                      f"{sfloor:.2f}", f"{ndev} dev", status))
        if not ok_eff:
            failures.append(
                f"fleet_campaign_scaling: scaling_efficiency_4dev "
                f"{eff:.2f} < floor {sfloor:.2f} (sharded stream "
                f"serialized or duplicated work)")
        if not ok_dev:
            failures.append(
                f"fleet_campaign_scaling: measured on {ndev} devices, "
                f"expected 4 — the forced-device child lost its XLA flag")
    # companion snapshots exist (content is informational — calibration
    # rows — but absence means the bench job dropped a section)
    bench_dir = os.path.dirname(os.path.abspath(path)) or "."
    for fname in COMPANION_FILES:
        fpath = os.path.join(bench_dir, fname)
        if not os.path.exists(fpath):
            failures.append(
                f"{fname}: companion benchmark snapshot missing from "
                f"{bench_dir} — run `PYTHONPATH=src:. python "
                f"benchmarks/allocator.py`")
    header = ("bench", "measured", "floor", "dispatches", "status")
    widths = [max(len(str(r[i])) for r in [header] + table)
              for i in range(len(header))]
    for r in [header] + table:
        print("  ".join(str(v).ljust(w) for v, w in zip(r, widths)))
    if failures:
        print("perf gate FAILED:\n  " + "\n  ".join(failures))
        return 1
    print(f"perf gate passed ({'smoke' if smoke else 'full'} floors)")
    return 0


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fleet.json"
    sys.exit(check(path))
