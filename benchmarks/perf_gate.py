"""CI perf gate: fail the job when the fleet warm path regresses.

Parses ``BENCH_fleet.json`` (written by ``benchmarks/fleet.py``) and
checks, per policy:

* ``speedup_warm`` against a checked-in floor, and
* ``n_dispatches == 1`` — the packed runtime's structural invariant: a
  warm fleet run is ONE fused executable. A solver or runner change that
  silently falls back to per-bucket dispatch fails the gate even if the
  wall-clock happens to look fine on the runner that day.

On failure (and success) the gate prints the full measured-vs-floor table,
so a red CI job shows every margin at a glance instead of a bare assert.

Two modes:

* **smoke** (``REPRO_SMOKE=1``, the CI runner): floors are deliberately
  conservative — the shared 2-core runner's wall-clock is noisy and the
  sequential baseline there is itself fast, so the gate only catches real
  regressions (e.g. a change that re-serializes the batch), not
  scheduling jitter.
* **full** (REPRO_SMOKE unset): asserts the ROADMAP target for the
  measured-and-re-scoped warm-path item.

    PYTHONPATH=src:. python benchmarks/perf_gate.py [path/to/BENCH_fleet.json]
"""
from __future__ import annotations

import json
import os
import sys

# Conservative smoke floors for the noisy 2-core CI runner: ~55-60% of
# the values measured on the same container class after the packed
# single-dispatch runtime landed (tcp 2.43, appaware 2.67 — see
# BENCH_fleet.json / ROADMAP; PR 4 recorded 1.92/2.22 and its floors were
# 1.2/1.3).
SMOKE_FLOORS = {"fleet_tcp": 1.35, "fleet_appaware": 1.5}
# Full-mode floors: the re-scoped warm-path item (ROADMAP "after PR 5"),
# asserted with ~25% slack for container variance (PR 4: 1.5/1.7).
FULL_FLOORS = {"fleet_tcp": 1.8, "fleet_appaware": 2.0}


def check(path: str) -> int:
    with open(path) as f:
        rows = json.load(f)
    smoke = os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0")
    floors = SMOKE_FLOORS if smoke else FULL_FLOORS
    by_name = {r.get("name"): r for r in rows}
    table, failures = [], []
    for name, floor in floors.items():
        row = by_name.get(name)
        if row is None:
            failures.append(f"{name}: missing from {path}")
            table.append((name, "missing", f"{floor:.2f}", "-", "MISSING"))
            continue
        got = float(row.get("speedup_warm", 0.0))
        disp = row.get("n_dispatches")
        ok_speed = got >= floor
        ok_disp = disp == 1
        status = "ok" if (ok_speed and ok_disp) else "REGRESSED"
        table.append((name, f"{got:.2f}", f"{floor:.2f}",
                      f"{disp}", status))
        if not ok_speed:
            failures.append(
                f"{name}: speedup_warm {got:.2f} < floor {floor:.2f}")
        if not ok_disp:
            failures.append(
                f"{name}: n_dispatches {disp} != 1 (packed runtime "
                f"fell back to per-bucket dispatch)")
    header = ("bench", "speedup_warm", "floor", "dispatches", "status")
    widths = [max(len(str(r[i])) for r in [header] + table)
              for i in range(len(header))]
    for r in [header] + table:
        print("  ".join(str(v).ljust(w) for v, w in zip(r, widths)))
    if failures:
        print("perf gate FAILED:\n  " + "\n  ".join(failures))
        return 1
    print(f"perf gate passed ({'smoke' if smoke else 'full'} floors)")
    return 0


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fleet.json"
    sys.exit(check(path))
