"""CI perf gate: fail the job when the fleet warm-path speedup regresses.

Parses ``BENCH_fleet.json`` (written by ``benchmarks/fleet.py``) and
asserts ``speedup_warm`` per policy against a checked-in floor. Two modes:

* **smoke** (``REPRO_SMOKE=1``, the CI runner): floors are deliberately
  conservative — the shared 2-core runner's wall-clock is noisy and the
  sequential baseline there is itself fast, so the gate only catches real
  regressions (e.g. a solver change that re-serializes the batch), not
  scheduling jitter.
* **full** (REPRO_SMOKE unset): asserts the ROADMAP target for the
  measured-and-re-scoped warm-path item.

    PYTHONPATH=src:. python benchmarks/perf_gate.py [path/to/BENCH_fleet.json]
"""
from __future__ import annotations

import json
import os
import sys

# Conservative smoke floors for the noisy 2-core CI runner: ~60% of the
# values measured on the same container class after the fused max-min
# solver landed (tcp 1.92, appaware 2.22 — see BENCH_fleet.json / ROADMAP;
# repeat runs on a contended core dipped as low as ~1.45/1.55).
SMOKE_FLOORS = {"fleet_tcp": 1.2, "fleet_appaware": 1.3}
# Full-mode floors: the re-scoped warm-path item (ROADMAP "after PR 4"):
# ≥ 1.9/2.2 measured on a quiet 2-core CPU, asserted with ~20% slack.
FULL_FLOORS = {"fleet_tcp": 1.5, "fleet_appaware": 1.7}


def check(path: str) -> int:
    with open(path) as f:
        rows = json.load(f)
    smoke = os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0")
    floors = SMOKE_FLOORS if smoke else FULL_FLOORS
    by_name = {r.get("name"): r for r in rows}
    failures = []
    for name, floor in floors.items():
        row = by_name.get(name)
        if row is None:
            failures.append(f"{name}: missing from {path}")
            continue
        got = float(row.get("speedup_warm", 0.0))
        status = "ok" if got >= floor else "REGRESSED"
        print(f"{name}: speedup_warm={got:.2f} floor={floor:.2f} [{status}]")
        if got < floor:
            failures.append(
                f"{name}: speedup_warm {got:.2f} < floor {floor:.2f}")
    if failures:
        print("perf gate FAILED:\n  " + "\n  ".join(failures))
        return 1
    print(f"perf gate passed ({'smoke' if smoke else 'full'} floors)")
    return 0


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fleet.json"
    sys.exit(check(path))
