"""CI perf gate: fail the job when the fleet warm path regresses.

Parses ``BENCH_fleet.json`` (written by ``benchmarks/fleet.py``) and
checks, per policy:

* ``speedup_warm`` against a checked-in floor,
* ``n_dispatches == 1`` — the packed runtime's structural invariant: a
  warm fleet run is ONE fused executable. A solver or runner change that
  silently falls back to per-bucket dispatch fails the gate even if the
  wall-clock happens to look fine on the runner that day, and
* the ``fleet_order_cache`` row — the order-cached max-min solver must
  rebuild its demand-rank operand exactly ONCE per scenario on the
  static-demand corpus scan (the tick-0 cold start). More than one means
  the O(F) order check is spuriously invalidating carried state (the
  order cache silently degrades to rebuild-every-tick); zero means the
  cold start stopped being counted, and
* the ``fleet_campaign`` row — the streaming campaign mode
  (``FleetRunner.run_campaign``) must keep its throughput within the
  floor of the materialized path on the same corpus
  (``stream_vs_materialized``: chunk staging re-done per call has to be
  paid for by its overlap with in-flight device compute) AND its host
  staging bounded (``peak_staged_rows`` ≤ 2 × ``chunk_rows`` — the two
  ping/pong slots; more means the bounded-memory property silently
  broke and a 10⁴-scenario campaign would materialize after all).

Missing input files are a hard, *loud* failure: benchmark snapshots are
checked into the repo (see ``.gitignore`` history — they used to be
ignored, which made "the gate passed" indistinguishable from "the gate
read nothing"), so an absent ``BENCH_*.json`` means the bench step was
skipped or its artifact lost, and the gate says exactly that instead of
raising a bare traceback.

On failure (and success) the gate prints the full measured-vs-floor table,
so a red CI job shows every margin at a glance instead of a bare assert.

Two modes:

* **smoke** (``REPRO_SMOKE=1``, the CI runner): floors are deliberately
  conservative — the shared CI runner's wall-clock is noisy and the
  sequential baseline there is itself fast, so the gate only catches real
  regressions (e.g. a change that re-serializes the batch), not
  scheduling jitter.
* **full** (REPRO_SMOKE unset): asserts the ROADMAP target for the
  measured-and-re-scoped warm-path item.

    PYTHONPATH=src:. python benchmarks/perf_gate.py [path/to/BENCH_fleet.json]
"""
from __future__ import annotations

import json
import os
import sys

# speedup_warm is strongly container-class dependent: the quiet 2-core
# container of PR 5 measured tcp 2.43 / appaware 2.67, while the loaded
# 1-core container that produced the committed BENCH_fleet.json measures
# 1.16 / 1.16 for the SAME code — op-dispatch contention slows the
# batched and sequential sides almost equally, so the ratio compresses
# toward 1 long before anything is actually wrong (interleaved A/B
# old-vs-new solver on that container: neutral on both sides, see
# ROADMAP item 1). Floors are therefore set to catch structural
# regressions — a batch path that re-serializes drops to <= 1.0 on ANY
# container — not to re-assert the quiet-container headline, which only
# the quiet-container BENCH refresh can do.
SMOKE_FLOORS = {"fleet_tcp": 1.05, "fleet_appaware": 1.05}
# Full-mode floors: a guard band under the weakest container class we
# have measured (1.16/1.16, loaded 1-core).
FULL_FLOORS = {"fleet_tcp": 1.1, "fleet_appaware": 1.1}

# Streaming-vs-materialized throughput floors (ratio of warm wall-clocks,
# same corpus, interleaved reps): ISSUE-7 target is >= 0.9x in full mode;
# smoke keeps a wider band for the noisy shared CI runner.
CAMPAIGN_SMOKE_FLOOR = 0.8
CAMPAIGN_FULL_FLOOR = 0.9

# Companion snapshots that must exist alongside the gate's own input —
# their absence means the bench job silently skipped a section.
COMPANION_FILES = ("BENCH_allocator.json",)


def _load(path: str):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def check(path: str) -> int:
    rows = _load(path)
    if rows is None:
        print(f"perf gate FAILED:\n  {path}: benchmark snapshot missing — "
              f"run `PYTHONPATH=src:. python benchmarks/fleet.py` (or "
              f"restore the committed BENCH_fleet.json); a missing input "
              f"is a gate failure, never a silent pass")
        return 1
    smoke = os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0")
    floors = SMOKE_FLOORS if smoke else FULL_FLOORS
    by_name = {r.get("name"): r for r in rows}
    table, failures = [], []
    for name, floor in floors.items():
        row = by_name.get(name)
        if row is None:
            failures.append(f"{name}: missing from {path}")
            table.append((name, "missing", f"{floor:.2f}", "-", "MISSING"))
            continue
        got = float(row.get("speedup_warm", 0.0))
        disp = row.get("n_dispatches")
        ok_speed = got >= floor
        ok_disp = disp == 1
        status = "ok" if (ok_speed and ok_disp) else "REGRESSED"
        table.append((name, f"{got:.2f}", f"{floor:.2f}",
                      f"{disp}", status))
        if not ok_speed:
            failures.append(
                f"{name}: speedup_warm {got:.2f} < floor {floor:.2f}")
        if not ok_disp:
            failures.append(
                f"{name}: n_dispatches {disp} != 1 (packed runtime "
                f"fell back to per-bucket dispatch)")
    # order-cache structural invariant: exactly one rebuild per scenario
    # on the static-demand corpus scan
    oc = by_name.get("fleet_order_cache")
    if oc is None:
        failures.append(f"fleet_order_cache: missing from {path}")
        table.append(("fleet_order_cache", "missing", "1/scenario", "-",
                      "MISSING"))
    else:
        lo = int(oc.get("static_demand_rebuilds_min", -1))
        hi = int(oc.get("static_demand_rebuilds_max", -1))
        ok = lo == 1 and hi == 1
        table.append(("fleet_order_cache", f"rebuilds {lo}..{hi}",
                      "1/scenario", "-", "ok" if ok else "REGRESSED"))
        if not ok:
            failures.append(
                f"fleet_order_cache: static-demand rebuilds per scenario "
                f"in [{lo}, {hi}], expected exactly 1 (order cache "
                f"{'over-invalidates' if hi > 1 else 'lost its cold-start count'})")
    # streaming campaign mode: throughput floor + bounded host staging
    cp = by_name.get("fleet_campaign")
    cfloor = CAMPAIGN_SMOKE_FLOOR if smoke else CAMPAIGN_FULL_FLOOR
    if cp is None:
        failures.append(f"fleet_campaign: missing from {path}")
        table.append(("fleet_campaign", "missing", f"{cfloor:.2f}", "-",
                      "MISSING"))
    else:
        ratio = float(cp.get("stream_vs_materialized", 0.0))
        peak = int(cp.get("peak_staged_rows", -1))
        crows = int(cp.get("chunk_rows", 0))
        ok_ratio = ratio >= cfloor
        ok_peak = 0 <= peak <= 2 * crows
        status = "ok" if (ok_ratio and ok_peak) else "REGRESSED"
        table.append(("fleet_campaign", f"{ratio:.2f}", f"{cfloor:.2f}",
                      f"peak {peak}/{2 * crows}", status))
        if not ok_ratio:
            failures.append(
                f"fleet_campaign: stream_vs_materialized {ratio:.2f} < "
                f"floor {cfloor:.2f} (streaming mode lost its overlap)")
        if not ok_peak:
            failures.append(
                f"fleet_campaign: peak_staged_rows {peak} > 2 x chunk_rows "
                f"{crows} — host staging is no longer bounded by the two "
                f"ping/pong slots")
    # companion snapshots exist (content is informational — calibration
    # rows — but absence means the bench job dropped a section)
    bench_dir = os.path.dirname(os.path.abspath(path)) or "."
    for fname in COMPANION_FILES:
        fpath = os.path.join(bench_dir, fname)
        if not os.path.exists(fpath):
            failures.append(
                f"{fname}: companion benchmark snapshot missing from "
                f"{bench_dir} — run `PYTHONPATH=src:. python "
                f"benchmarks/allocator.py`")
    header = ("bench", "measured", "floor", "dispatches", "status")
    widths = [max(len(str(r[i])) for r in [header] + table)
              for i in range(len(header))]
    for r in [header] + table:
        print("  ".join(str(v).ljust(w) for v, w in zip(r, widths)))
    if failures:
        print("perf gate FAILED:\n  " + "\n  ".join(failures))
        return 1
    print(f"perf gate passed ({'smoke' if smoke else 'full'} floors)")
    return 0


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fleet.json"
    sys.exit(check(path))
