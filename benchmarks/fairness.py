"""Fig. 13 (§VII): application-level fairness among 5 competing apps with
1..5 flows each. Paper: Jain index — TCP 0.84; App-Fair 0.98–0.99 across
α ∈ {0.25, 0.5, 0.75, 1.0} at Δt = 10 s."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import AppFairScheduler, jain_index, maxmin_rates


def run(seconds: int = 600, dt_alloc: float = 10.0) -> list[dict]:
    n_apps = 5
    app_of_flow = np.concatenate([[a] * (a + 1) for a in range(n_apps)])
    F = len(app_of_flow)
    R = jnp.ones((F, 1), jnp.float32)
    cap = jnp.array([100.0])
    x_tcp = np.asarray(maxmin_rates(R, cap))
    tcp_app = np.array([x_tcp[app_of_flow == a].sum() for a in range(n_apps)])
    j_tcp = float(jain_index(jnp.asarray(tcp_app)))

    rows = [{
        "name": "fig13_fairness_TCP",
        "jain": round(j_tcp, 3),
        "per_app": "/".join(f"{t:.0f}" for t in tcp_app),
    }]
    intervals = int(seconds / dt_alloc)
    for alpha in (0.25, 0.5, 0.75, 1.0):
        sched = AppFairScheduler(n_apps, alpha=alpha, n_groups=5)
        state = sched.init()
        aof = jnp.asarray(app_of_flow)
        total = np.zeros(n_apps)
        prev = np.zeros(n_apps, np.float32)
        for _ in range(intervals):
            state, x = sched.step(state, jnp.asarray(prev), R, cap, aof)
            xn = np.asarray(x)
            per = np.array([xn[app_of_flow == a].sum()
                            for a in range(n_apps)])
            total += per
            prev = per.astype(np.float32)
        j = float(jain_index(jnp.asarray(total / intervals)))
        rows.append({
            "name": f"fig13_fairness_AppFair_alpha{alpha}",
            "jain": round(j, 3),
            "per_app": "/".join(f"{t:.0f}" for t in total / intervals),
        })
    return rows


def main() -> None:
    emit(run(), "fig13")


if __name__ == "__main__":
    main()
