"""Allocator hot-path micro-benchmark: per-interval ``allocate`` cost at
datacenter scale (ROADMAP: 10⁴ links × 10³ flows).

Alg. 1 re-solves every Δt, so the per-interval solve is the controller's
steady-state cost. Three paths over the same random LinkProgram/FlowState:

  * ``sort``   — the fused batched solve (`allocator._per_link_rates`):
                 ONE global argsort over flows + masked batched cumsums;
  * ``vmap``   — the pre-fusion reference (`_per_link_rates_vmap`):
                 one argsort *per link* under `jax.vmap` (kept as the
                 parity oracle; benchmarked here to track the fusion win);
  * ``pallas`` — the bisection waterfill kernel (TPU target; interpret
                 mode off-TPU, so CPU numbers measure the kernel's control
                 flow, not TPU performance).

Sizes: {10², 10³, 10⁴} links × 10³ flows. ``REPRO_SMOKE=1`` (CI) caps the
sweep at 10³ links and skips the interpret-mode pallas point beyond 10²
(unrolling a 10³-link grid through the interpreter is compile-bound).

    PYTHONPATH=src python benchmarks/allocator.py
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit_us
from repro.core.allocator import (
    LinkProgram,
    _per_link_rates,
    _per_link_rates_vmap,
    allocate,
)
from repro.core.flowstate import FlowState

N_FLOWS = 1_000
LINK_SIZES = (100, 1_000, 10_000)
SMOKE = os.environ.get("REPRO_SMOKE", "").strip() not in ("", "0")
DT = 5.0


def _mk_problem(L: int, F: int = N_FLOWS, seed: int = 0,
                links_per_flow: int = 4) -> tuple[LinkProgram, FlowState]:
    """Sparse random program: each flow crosses ~``links_per_flow`` links;
    kinds split uplink/downlink/internal like a fat-tree."""
    rng = np.random.default_rng(seed)
    R = np.zeros((F, L), np.float32)
    for f in range(F):
        R[f, rng.choice(L, size=min(links_per_flow, L), replace=False)] = 1.0
    kind = rng.choice([0, 1, 2], size=L, p=[0.4, 0.4, 0.2]).astype(np.int32)
    prog = LinkProgram(
        R=jnp.asarray(R),
        capacity=jnp.asarray(rng.uniform(1.0, 50.0, L), jnp.float32),
        kind=jnp.asarray(kind),
    )
    st = FlowState(*[jnp.asarray(rng.uniform(0, 10, F), jnp.float32)
                     for _ in range(5)])
    return prog, st


@functools.partial(jax.jit, static_argnames=("dt",))
def _vmap_rates(program, state, dt):
    return _per_link_rates_vmap(program, state, dt)


@functools.partial(jax.jit, static_argnames=("dt",))
def _fused_rates(program, state, dt):
    return _per_link_rates(program, state, dt)


def run() -> list[dict]:
    rows = []
    sizes = [s for s in LINK_SIZES if not (SMOKE and s > 1_000)]
    for L in sizes:
        prog, st = _mk_problem(L)
        iters = max(2, min(10, 20_000 // L))

        us_sort = timeit_us(
            lambda: jax.block_until_ready(
                allocate(prog, st, dt=DT, solver="sort")), iters)
        us_fused = timeit_us(
            lambda: jax.block_until_ready(_fused_rates(prog, st, DT)), iters)
        us_vmap = timeit_us(
            lambda: jax.block_until_ready(_vmap_rates(prog, st, DT)), iters)
        # chunked-links variant: bounded [block, F] working set — the
        # memory-capped path for datacenter link counts
        blk = min(L, 256)
        us_chunk = timeit_us(
            lambda: jax.block_until_ready(
                allocate(prog, st, dt=DT, solver="sort", block_links=blk)),
            iters)
        row = {
            "name": f"alloc_L{L}",
            "us_per_call": us_sort,
            "n_links": L,
            "n_flows": N_FLOWS,
            "backend": jax.default_backend(),
            "allocate_sort_us": round(us_sort, 1),
            "allocate_chunked_us": round(us_chunk, 1),
            "block_links": blk,
            "per_link_fused_us": round(us_fused, 1),
            "per_link_vmap_us": round(us_vmap, 1),
            "fused_over_vmap": round(us_vmap / max(us_fused, 1e-9), 2),
        }
        # interpret-mode pallas walks the grid in python: keep CI (smoke)
        # to the small grid, measure every size in full runs / on TPU
        if jax.default_backend() == "tpu" or not SMOKE or L <= 100:
            us_pal = timeit_us(
                lambda: jax.block_until_ready(
                    allocate(prog, st, dt=DT, solver="pallas")),
                max(2, iters // 2))
            row["allocate_pallas_us"] = round(us_pal, 1)
        rows.append(row)
    return rows


def _mk_maxmin(F: int, L: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    R = np.zeros((F, L), np.float32)
    for f in range(F):
        R[f, rng.choice(L, size=min(3, L), replace=False)] = 1.0
    cap = rng.uniform(1.0, 20.0, L).astype(np.float32)
    d = rng.uniform(0.0, 10.0, F).astype(np.float32)
    return jnp.asarray(R), jnp.asarray(cap), jnp.asarray(d)


def run_maxmin() -> list[dict]:
    """Max-min solver micro-bench: the fused fixed-trip fill
    (`maxmin_fused`, the tcp/appfair hot path) vs the retained while-loop
    progressive-filling oracle (`demand_limited_maxmin`), single-instance
    and under an 8-wide `vmap` (the fleet engine's shape) — the while
    loop's data-dependent trip count runs at the batch max under vmap,
    which is exactly what the fixed-trip rewrite removes."""
    from repro.core.tcp import demand_limited_maxmin, maxmin_fused

    fused = jax.jit(maxmin_fused)
    loop = jax.jit(demand_limited_maxmin)
    vfused = jax.jit(jax.vmap(maxmin_fused, in_axes=(0, 0, 0)))
    vloop = jax.jit(jax.vmap(demand_limited_maxmin, in_axes=(0, 0, 0)))
    rows = []
    for F, L in ((64, 24), (512, 64)):
        if SMOKE and F > 64:
            continue
        R, cap, d = _mk_maxmin(F, L)
        Rb, capb, db = (jnp.stack([a] * 8) for a in (R, cap, d))
        us_f = timeit_us(lambda: jax.block_until_ready(fused(R, cap, d)), 20)
        us_l = timeit_us(lambda: jax.block_until_ready(loop(R, cap, d)), 20)
        us_vf = timeit_us(
            lambda: jax.block_until_ready(vfused(Rb, capb, db)), 20)
        us_vl = timeit_us(
            lambda: jax.block_until_ready(vloop(Rb, capb, db)), 20)
        rows.append({
            "name": f"maxmin_F{F}_L{L}",
            "us_per_call": us_f,
            "backend": jax.default_backend(),
            "fused_us": round(us_f, 1),
            "while_oracle_us": round(us_l, 1),
            "fused_vmap8_us": round(us_vf, 1),
            "while_vmap8_us": round(us_vl, 1),
            "fused_over_while": round(us_l / max(us_f, 1e-9), 2),
            "fused_over_while_vmap8": round(us_vl / max(us_vf, 1e-9), 2),
        })
    return rows


def run_crossover() -> list[dict]:
    """Calibration rows for ``MAXMIN_CROSSOVER_F`` — the trace-time
    dispatch between the rank-prefix GEMM form (O(F²·L), order-cacheable,
    one matmul per round) and the argsort+cumsum form (O(F·L), batched
    gathers/scans) of the fused solver's water-level evaluation. Both
    forms are timed at a grid of flow counts straddling the constant,
    single-instance and vmap-8 (the fleet engine's batching shape, where
    per-member sorts serialize on CPU and the GEMM form's advantage is
    largest). The shipped constant must sit inside the measured crossover
    band of the vmap-8 column: the solver's only batched consumer is the
    fleet engine."""
    from repro.core.tcp import MAXMIN_CROSSOVER_F, maxmin_fused

    grid = (32, 96, 192, 256, 384, 512)
    if SMOKE:
        grid = (32, 96)
    rows = []
    for F in grid:
        L = max(16, F // 8)
        R, cap, d = _mk_maxmin(F, L, seed=1)
        Rb, capb, db = (jnp.stack([a] * 8) for a in (R, cap, d))
        row = {"name": f"maxmin_crossover_F{F}", "n_flows": F, "n_links": L,
               "backend": jax.default_backend(),
               "crossover_f": MAXMIN_CROSSOVER_F}
        for form in ("gemm", "sorted"):
            one = jax.jit(functools.partial(maxmin_fused, form=form))
            vm = jax.jit(jax.vmap(functools.partial(maxmin_fused, form=form),
                                  in_axes=(0, 0, 0)))
            row[f"{form}_us"] = round(timeit_us(
                lambda: jax.block_until_ready(one(R, cap, d)), 20), 1)
            row[f"{form}_vmap8_us"] = round(timeit_us(
                lambda: jax.block_until_ready(vm(Rb, capb, db)), 20), 1)
        row["us_per_call"] = row["gemm_vmap8_us"]
        row["gemm_over_sorted_vmap8"] = round(
            row["gemm_vmap8_us"] / max(row["sorted_vmap8_us"], 1e-9), 3)
        rows.append(row)
    return rows


def main() -> None:
    emit(run() + run_maxmin() + run_crossover(), "allocator")


if __name__ == "__main__":
    main()
